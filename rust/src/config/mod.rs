//! JSON configuration system for the `kan-edge` binary.
//!
//! A single [`AppConfig`] covers the serving runtime, the hardware model,
//! and the NeuroSim search budgets; every subcommand takes `--config
//! <file>` plus CLI overrides. A missing file means all defaults, so the
//! quickstart works with zero setup. (The offline image carries no TOML
//! parser, so config files are JSON — parsed by [`crate::util::json`].)

use std::path::Path;

use crate::acim::AcimOptions;
use crate::circuits::Tech;
use crate::coordinator::backend::BackendKind;
use crate::error::{Error, Result};
use crate::neurosim::HwConstraints;
use crate::util::json::Value;

/// Top-level configuration.
#[derive(Debug, Clone, Default)]
pub struct AppConfig {
    pub artifacts: ArtifactsConfig,
    pub server: ServerConfig,
    pub scheduler: SchedulerConfig,
    pub registry: RegistryConfig,
    pub hardware: HardwareConfig,
    pub neurosim: NeurosimConfig,
    pub observability: ObservabilityConfig,
    pub cluster: ClusterConfig,
    pub rollout: RolloutConfig,
}

#[derive(Debug, Clone)]
pub struct ArtifactsConfig {
    /// Directory holding manifest.json & friends (built by `make artifacts`).
    pub dir: String,
    /// Default model to serve.
    pub model: String,
}

impl Default for ArtifactsConfig {
    fn default() -> Self {
        Self { dir: "artifacts".into(), model: "kan1".into() }
    }
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max batch the dynamic batcher will close.
    pub max_batch: usize,
    /// Batching deadline in microseconds.
    pub batch_deadline_us: u64,
    /// Bound on queued requests before admission control rejects.
    pub queue_depth: usize,
    /// Number of backend workers.
    pub workers: usize,
    /// Primary execution backend, parsed from the file's `"backend"`
    /// string exactly once at config load ("pjrt" | "digital" | "acim";
    /// mlp artifacts always execute the mlp path).
    pub backend: BackendKind,
    /// Digital backend execution path: `true` (default) compiles the
    /// checkpoint into the planned [`crate::kan::KanEngine`]
    /// (integer-exact hot path, `docs/ENGINE.md`); `false` serves the
    /// scalar golden reference (`QuantKanModel::forward_batch`).
    pub engine: bool,
    /// Max bytes in one wire request (v1 line or v2 frame payload); an
    /// oversized request gets a structured `too_large` error and only
    /// that connection is dropped.
    pub max_request_bytes: usize,
    /// Max concurrently dispatched v2 requests per connection
    /// (pipelining depth); the connection reader blocks once reached.
    pub max_in_flight: usize,
    /// Shadow execution (`"shadow"` object in the `server` section):
    /// mirror a sampled fraction of served traffic onto a second
    /// backend off the response path, recording divergence metrics.
    pub shadow: ShadowConfig,
}

/// `server.shadow` — shadow-mirror knobs (see `docs/BACKENDS.md`).
#[derive(Debug, Clone)]
pub struct ShadowConfig {
    /// Mirror backend; `None` disables shadow execution.
    pub backend: Option<BackendKind>,
    /// Fraction of primary rows mirrored, in (0, 1].
    pub fraction: f64,
    /// Bound on queued mirror jobs; overflow drops (never blocks the
    /// primary response path).
    pub queue: usize,
}

impl Default for ShadowConfig {
    fn default() -> Self {
        Self { backend: None, fraction: 0.1, queue: 256 }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        // wire limits share one source of truth with servers spawned
        // without a config (TcpServer::spawn uses TcpLimits::default)
        let wire = crate::coordinator::tcp::TcpLimits::default();
        Self {
            max_batch: 32,
            batch_deadline_us: 500,
            queue_depth: 1024,
            workers: 2,
            // without the pjrt feature the AOT path is a stub, so the
            // rust integer reference is the sensible default
            backend: if cfg!(all(feature = "pjrt", feature = "xla")) {
                BackendKind::Pjrt
            } else {
                BackendKind::Digital
            },
            engine: true,
            max_request_bytes: wire.max_request_bytes,
            max_in_flight: wire.max_in_flight,
            shadow: ShadowConfig::default(),
        }
    }
}

/// `[scheduler]` — fair-admission knobs (see
/// [`crate::coordinator::scheduler`] and `docs/SCHEDULING.md`).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Admission policy: `"fifo"` (seed behavior: one global bounded
    /// queue) or `"drr"` (deficit-round-robin across clients with
    /// per-client quotas).
    pub policy: String,
    /// Max in-queue rows per client before admission rejects with a
    /// structured `overloaded` + `retry_after_ms` (`drr` only).
    pub quota: usize,
    /// Rows drained from one client before rotating to the next (`drr`
    /// quantum).
    pub fairness_window: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { policy: "fifo".into(), quota: 64, fairness_window: 8 }
    }
}

/// `[registry]` — multi-model serving knobs (see [`crate::registry`]).
#[derive(Debug, Clone)]
pub struct RegistryConfig {
    /// Max live (loaded) model backends before LRU eviction kicks in.
    pub max_loaded: usize,
    /// Hot-reload poll interval in milliseconds; 0 disables polling.
    pub reload_poll_ms: u64,
    /// Models to load eagerly at `serve` start (default model when empty).
    pub preload: Vec<String>,
    /// Content-addressed store directory, relative to the artifacts dir.
    pub store_dir: String,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        Self {
            max_loaded: 4,
            reload_poll_ms: 0,
            preload: Vec::new(),
            store_dir: ".store".into(),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct HardwareConfig {
    /// 22 nm technology constants.
    pub tech: Tech,
    /// ACIM simulation options (array geometry, non-idealities).
    pub acim: AcimOptions,
}

#[derive(Debug, Clone)]
pub struct NeurosimConfig {
    pub constraints: HwConstraints,
    /// TM-DV-IG voltage-bit modes to search over.
    pub tm_modes: Vec<u32>,
}

impl Default for NeurosimConfig {
    fn default() -> Self {
        Self { constraints: HwConstraints::default(), tm_modes: vec![2, 3, 4] }
    }
}

/// `[observability]` — tracing, engine profiling and logging knobs
/// (see `docs/OBSERVABILITY.md`).
#[derive(Debug, Clone)]
pub struct ObservabilityConfig {
    /// Deterministic request-trace sampling: every Nth served v2
    /// `infer` request carries a span. 0 disables tracing entirely.
    pub sample_every: u64,
    /// Completed-span ring-buffer capacity (the `trace` verb's window).
    pub trace_ring: usize,
    /// Opt-in engine profiling counters (tiles touched, fused hits,
    /// interval occupancy vs the SAM calibration prior). Off by
    /// default: off means zero extra work on the engine inner loop.
    pub engine_profiling: bool,
    /// Structured-logger level: `"error" | "warn" | "info" | "debug"`.
    /// The `KAN_EDGE_LOG` environment variable overrides this.
    pub log_level: String,
}

impl Default for ObservabilityConfig {
    fn default() -> Self {
        Self {
            sample_every: 16,
            trace_ring: 256,
            engine_profiling: false,
            log_level: "info".into(),
        }
    }
}

/// `[cluster]` — front-router knobs for `kan-edge route` (see
/// [`crate::cluster`] and `docs/CLUSTER.md`). Only the router reads
/// this section; `serve` nodes ignore it.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Backend node addresses (`host:port`), in ring-identity order —
    /// every router sharing this list computes the same placement.
    pub nodes: Vec<String>,
    /// Replicas per model spec, primary included.
    pub replication: usize,
    /// Virtual nodes per physical node on the hash ring.
    pub vnodes: usize,
    /// Heartbeat probe period in milliseconds; 0 disables the loop
    /// (data-path failures still drive membership).
    pub heartbeat_ms: u64,
    /// Consecutive probe/data-path failures before a node is `Down`.
    pub fail_after: u32,
    /// Hedged retries for single-row requests.
    pub hedge: bool,
    /// Latency quantile the hedge delay is derived from, in (0, 1].
    pub hedge_quantile: f64,
    /// Clamp on the derived hedge delay, milliseconds.
    pub hedge_min_ms: u64,
    pub hedge_max_ms: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        let r = crate::cluster::RouterOptions::default();
        Self {
            nodes: Vec::new(),
            replication: r.replication,
            vnodes: r.vnodes,
            heartbeat_ms: r.heartbeat_ms,
            fail_after: r.fail_after,
            hedge: r.hedge,
            hedge_quantile: r.hedge_quantile,
            hedge_min_ms: r.hedge_min_ms,
            hedge_max_ms: r.hedge_max_ms,
        }
    }
}

impl ClusterConfig {
    /// The tuning subset, in the shape [`crate::cluster::ClusterRouter`]
    /// takes (everything but the node list).
    pub fn router_options(&self) -> crate::cluster::RouterOptions {
        crate::cluster::RouterOptions {
            replication: self.replication,
            vnodes: self.vnodes,
            heartbeat_ms: self.heartbeat_ms,
            fail_after: self.fail_after,
            hedge: self.hedge,
            hedge_quantile: self.hedge_quantile,
            hedge_min_ms: self.hedge_min_ms,
            hedge_max_ms: self.hedge_max_ms,
        }
    }
}

/// `[rollout]` — SLO gates and ramp schedule for staged canary
/// deployments (see [`crate::rollout`] and `docs/ROLLOUT.md`). The gates
/// are evaluated once per observation window; every gate must hold for a
/// full window to advance the ramp, and any breach triggers an instant
/// rollback to the pinned baseline.
#[derive(Debug, Clone)]
pub struct RolloutConfig {
    /// Canary traffic fractions for the `Ramping` steps, in [0, 1],
    /// non-decreasing. The terminal `Observing` step always runs at
    /// fraction 1.0, so the schedule need not end with 1.0. An entry of
    /// 0.0 keeps all traffic on the baseline while the split machinery
    /// runs (used by `bench-net` to price the splitter).
    pub ramp: Vec<f64>,
    /// Observation window per step, milliseconds.
    pub window_ms: u64,
    /// Minimum canary samples a window needs before the gates are
    /// evaluated; a starved window extends instead of deciding.
    pub min_samples: usize,
    /// Gate: max fraction of mirrored rows whose argmax class flips
    /// between baseline and canary, in [0, 1].
    pub max_flip_rate: f64,
    /// Gate: max p99 of the per-row mean absolute logit error between
    /// baseline and canary.
    pub max_logit_mae_p99: f64,
    /// Gate: max canary p99 latency as a multiple of the baseline p99
    /// (1.5 = canary may be at most 50% slower), >= 1.0.
    pub max_latency_regression: f64,
    /// Bound on queued divergence-mirror jobs; overflow drops the
    /// mirror (never blocks the serving path).
    pub queue: usize,
    /// Controller tick period, milliseconds: how often windows are
    /// checked for expiry.
    pub poll_ms: u64,
}

impl Default for RolloutConfig {
    fn default() -> Self {
        Self {
            ramp: vec![0.05, 0.25, 0.5],
            window_ms: 2000,
            min_samples: 50,
            max_flip_rate: 0.01,
            max_logit_mae_p99: 0.05,
            max_latency_regression: 1.5,
            queue: 256,
            poll_ms: 50,
        }
    }
}

fn get_f64(v: &Value, key: &str, dst: &mut f64) {
    if let Some(x) = v.get(key).and_then(|x| x.as_f64()) {
        *dst = x;
    }
}

fn get_usize(v: &Value, key: &str, dst: &mut usize) {
    if let Some(x) = v.get(key).and_then(|x| x.as_usize()) {
        *dst = x;
    }
}

fn get_u32(v: &Value, key: &str, dst: &mut u32) {
    if let Some(x) = v.get(key).and_then(|x| x.as_i64()) {
        *dst = x as u32;
    }
}

fn get_u64(v: &Value, key: &str, dst: &mut u64) {
    if let Some(x) = v.get(key).and_then(|x| x.as_i64()) {
        *dst = x as u64;
    }
}

fn get_bool(v: &Value, key: &str, dst: &mut bool) {
    if let Some(x) = v.get(key).and_then(|x| x.as_bool()) {
        *dst = x;
    }
}

fn get_string(v: &Value, key: &str, dst: &mut String) {
    if let Some(x) = v.get(key).and_then(|x| x.as_str()) {
        *dst = x.to_string();
    }
}

impl AppConfig {
    /// Load from a JSON file, or defaults when `path` is `None`. Unknown
    /// keys are ignored; missing keys keep their defaults.
    pub fn load(path: Option<&Path>) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p).map_err(|e| {
                Error::Config(format!("cannot read config {}: {e}", p.display()))
            })?;
            let v = Value::parse(&text)
                .map_err(|e| Error::Config(format!("{}: {e}", p.display())))?;
            cfg.apply(&v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Overlay a parsed JSON document onto the current config. Backend
    /// names are parsed to [`BackendKind`] here — the one place a
    /// backend string exists — so an unknown name fails the load with
    /// an actionable error instead of surviving to dispatch time.
    pub fn apply(&mut self, v: &Value) -> Result<()> {
        if let Some(a) = v.get("artifacts") {
            get_string(a, "dir", &mut self.artifacts.dir);
            get_string(a, "model", &mut self.artifacts.model);
        }
        if let Some(s) = v.get("server") {
            get_usize(s, "max_batch", &mut self.server.max_batch);
            get_u64(s, "batch_deadline_us", &mut self.server.batch_deadline_us);
            get_usize(s, "queue_depth", &mut self.server.queue_depth);
            get_usize(s, "workers", &mut self.server.workers);
            if let Some(b) = s.get("backend").and_then(|x| x.as_str()) {
                self.server.backend = BackendKind::parse(b)?;
            }
            get_bool(s, "engine", &mut self.server.engine);
            get_usize(s, "max_request_bytes", &mut self.server.max_request_bytes);
            get_usize(s, "max_in_flight", &mut self.server.max_in_flight);
            if let Some(sh) = s.get("shadow") {
                if let Some(b) = sh.get("backend").and_then(|x| x.as_str()) {
                    self.server.shadow.backend = Some(BackendKind::parse(b)?);
                }
                get_f64(sh, "fraction", &mut self.server.shadow.fraction);
                get_usize(sh, "queue", &mut self.server.shadow.queue);
            }
        }
        if let Some(s) = v.get("scheduler") {
            get_string(s, "policy", &mut self.scheduler.policy);
            get_usize(s, "quota", &mut self.scheduler.quota);
            get_usize(s, "fairness_window", &mut self.scheduler.fairness_window);
        }
        if let Some(r) = v.get("registry") {
            get_usize(r, "max_loaded", &mut self.registry.max_loaded);
            get_u64(r, "reload_poll_ms", &mut self.registry.reload_poll_ms);
            get_string(r, "store_dir", &mut self.registry.store_dir);
            if let Some(p) = r.get("preload").and_then(|x| x.as_array()) {
                self.registry.preload = p
                    .iter()
                    .filter_map(|m| m.as_str())
                    .map(|m| m.to_string())
                    .collect();
            }
        }
        if let Some(h) = v.get("hardware") {
            if let Some(t) = h.get("tech") {
                let tech = &mut self.hardware.tech;
                get_f64(t, "vdd", &mut tech.vdd);
                get_f64(t, "gate_area_um2", &mut tech.gate_area_um2);
                get_f64(t, "gate_energy_fj", &mut tech.gate_energy_fj);
                get_f64(t, "sram_bit_area_um2", &mut tech.sram_bit_area_um2);
                get_f64(t, "rram_cell_area_um2", &mut tech.rram_cell_area_um2);
                get_f64(t, "unit_pulse_ns", &mut tech.unit_pulse_ns);
                get_f64(t, "adc_area_um2", &mut tech.adc_area_um2);
                get_f64(t, "adc_energy_fj", &mut tech.adc_energy_fj);
                get_f64(t, "adc_time_ns", &mut tech.adc_time_ns);
                get_usize(t, "adc_share", &mut tech.adc_share);
                get_f64(t, "routing_factor", &mut tech.routing_factor);
            }
            if let Some(a) = h.get("acim") {
                let acim = &mut self.hardware.acim;
                if let Some(arr) = a.get("array") {
                    get_usize(arr, "rows", &mut acim.array.rows);
                    get_usize(arr, "cols", &mut acim.array.cols);
                    get_f64(arr, "r_wire_ohm", &mut acim.array.r_wire_ohm);
                    get_f64(arr, "g_lrs_us", &mut acim.array.g_lrs_us);
                    get_f64(arr, "g_hrs_us", &mut acim.array.g_hrs_us);
                    get_u32(arr, "levels", &mut acim.array.levels);
                    get_f64(arr, "v_read", &mut acim.array.v_read);
                    get_f64(arr, "sigma_program", &mut acim.array.sigma_program);
                    get_f64(arr, "sigma_read", &mut acim.array.sigma_read);
                }
                get_u32(a, "adc_bits", &mut acim.adc_bits);
                get_f64(a, "adc_fs_factor", &mut acim.adc_fs_factor);
                get_bool(a, "irdrop", &mut acim.irdrop);
                get_bool(a, "noise", &mut acim.noise);
                get_u64(a, "seed", &mut acim.seed);
            }
        }
        if let Some(o) = v.get("observability") {
            get_u64(o, "sample_every", &mut self.observability.sample_every);
            get_usize(o, "trace_ring", &mut self.observability.trace_ring);
            get_bool(o, "engine_profiling", &mut self.observability.engine_profiling);
            get_string(o, "log_level", &mut self.observability.log_level);
        }
        if let Some(c) = v.get("cluster") {
            if let Some(nodes) = c.get("nodes").and_then(|x| x.as_array()) {
                self.cluster.nodes = nodes
                    .iter()
                    .filter_map(|n| n.as_str())
                    .map(|n| n.to_string())
                    .collect();
            }
            get_usize(c, "replication", &mut self.cluster.replication);
            get_usize(c, "vnodes", &mut self.cluster.vnodes);
            get_u64(c, "heartbeat_ms", &mut self.cluster.heartbeat_ms);
            get_u32(c, "fail_after", &mut self.cluster.fail_after);
            get_bool(c, "hedge", &mut self.cluster.hedge);
            get_f64(c, "hedge_quantile", &mut self.cluster.hedge_quantile);
            get_u64(c, "hedge_min_ms", &mut self.cluster.hedge_min_ms);
            get_u64(c, "hedge_max_ms", &mut self.cluster.hedge_max_ms);
        }
        if let Some(r) = v.get("rollout") {
            if let Some(ramp) = r.get("ramp").and_then(|x| x.as_array()) {
                self.rollout.ramp = ramp.iter().filter_map(|f| f.as_f64()).collect();
            }
            get_u64(r, "window_ms", &mut self.rollout.window_ms);
            get_usize(r, "min_samples", &mut self.rollout.min_samples);
            get_f64(r, "max_flip_rate", &mut self.rollout.max_flip_rate);
            get_f64(r, "max_logit_mae_p99", &mut self.rollout.max_logit_mae_p99);
            get_f64(
                r,
                "max_latency_regression",
                &mut self.rollout.max_latency_regression,
            );
            get_usize(r, "queue", &mut self.rollout.queue);
            get_u64(r, "poll_ms", &mut self.rollout.poll_ms);
        }
        if let Some(n) = v.get("neurosim") {
            if let Some(c) = n.get("constraints") {
                self.neurosim.constraints.max_area_mm2 =
                    c.get("max_area_mm2").and_then(|x| x.as_f64());
                self.neurosim.constraints.max_energy_pj =
                    c.get("max_energy_pj").and_then(|x| x.as_f64());
                self.neurosim.constraints.max_latency_ns =
                    c.get("max_latency_ns").and_then(|x| x.as_f64());
            }
            if let Some(modes) = n.get("tm_modes").and_then(|m| m.as_array()) {
                let parsed: Vec<u32> = modes
                    .iter()
                    .filter_map(|m| m.as_i64())
                    .map(|m| m as u32)
                    .collect();
                if !parsed.is_empty() {
                    self.neurosim.tm_modes = parsed;
                }
            }
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.server.max_batch == 0 {
            return Err(Error::Config("server.max_batch must be > 0".into()));
        }
        if self.server.workers == 0 {
            return Err(Error::Config("server.workers must be > 0".into()));
        }
        if let Some(shadow) = self.server.shadow.backend {
            if shadow == self.server.backend {
                return Err(Error::Config(format!(
                    "server.shadow.backend '{shadow}' mirrors the primary backend \
                     — a shadow must differ to measure divergence"
                )));
            }
            if !(self.server.shadow.fraction > 0.0 && self.server.shadow.fraction <= 1.0)
            {
                return Err(Error::Config(
                    "server.shadow.fraction must be in (0, 1]".into(),
                ));
            }
            if self.server.shadow.queue == 0 {
                return Err(Error::Config("server.shadow.queue must be > 0".into()));
            }
        }
        if self.server.max_request_bytes == 0 {
            return Err(Error::Config("server.max_request_bytes must be > 0".into()));
        }
        if self.server.max_in_flight == 0 {
            return Err(Error::Config("server.max_in_flight must be > 0".into()));
        }
        if !matches!(self.scheduler.policy.as_str(), "fifo" | "drr") {
            return Err(Error::Config(format!(
                "unknown scheduler.policy '{}' (fifo | drr)",
                self.scheduler.policy
            )));
        }
        if self.scheduler.quota == 0 {
            return Err(Error::Config("scheduler.quota must be > 0".into()));
        }
        if self.scheduler.fairness_window == 0 {
            return Err(Error::Config("scheduler.fairness_window must be > 0".into()));
        }
        if self.registry.max_loaded == 0 {
            return Err(Error::Config("registry.max_loaded must be > 0".into()));
        }
        if self.registry.store_dir.is_empty() {
            return Err(Error::Config("registry.store_dir must be non-empty".into()));
        }
        if self.observability.trace_ring == 0 {
            return Err(Error::Config("observability.trace_ring must be > 0".into()));
        }
        if crate::obs::log::Level::parse(&self.observability.log_level).is_none() {
            return Err(Error::Config(format!(
                "unknown observability.log_level '{}' (error | warn | info | debug)",
                self.observability.log_level
            )));
        }
        if self.cluster.replication == 0 {
            return Err(Error::Config("cluster.replication must be > 0".into()));
        }
        if self.cluster.vnodes == 0 {
            return Err(Error::Config("cluster.vnodes must be > 0".into()));
        }
        if self.cluster.fail_after == 0 {
            return Err(Error::Config("cluster.fail_after must be > 0".into()));
        }
        if !(self.cluster.hedge_quantile > 0.0 && self.cluster.hedge_quantile <= 1.0) {
            return Err(Error::Config(
                "cluster.hedge_quantile must be in (0, 1]".into(),
            ));
        }
        if self.cluster.hedge_min_ms > self.cluster.hedge_max_ms {
            return Err(Error::Config(
                "cluster.hedge_min_ms must be <= cluster.hedge_max_ms".into(),
            ));
        }
        for (i, f) in self.rollout.ramp.iter().enumerate() {
            if !(*f >= 0.0 && *f <= 1.0) {
                return Err(Error::Config(format!(
                    "rollout.ramp[{i}] must be in [0, 1] (got {f})"
                )));
            }
            if i > 0 && *f < self.rollout.ramp[i - 1] {
                return Err(Error::Config(
                    "rollout.ramp must be non-decreasing".into(),
                ));
            }
        }
        if self.rollout.window_ms == 0 {
            return Err(Error::Config("rollout.window_ms must be > 0".into()));
        }
        if self.rollout.min_samples == 0 {
            return Err(Error::Config("rollout.min_samples must be > 0".into()));
        }
        if !(self.rollout.max_flip_rate >= 0.0 && self.rollout.max_flip_rate <= 1.0) {
            return Err(Error::Config(
                "rollout.max_flip_rate must be in [0, 1]".into(),
            ));
        }
        if self.rollout.max_logit_mae_p99 < 0.0 {
            return Err(Error::Config(
                "rollout.max_logit_mae_p99 must be >= 0".into(),
            ));
        }
        if self.rollout.max_latency_regression < 1.0 {
            return Err(Error::Config(
                "rollout.max_latency_regression must be >= 1.0".into(),
            ));
        }
        if self.rollout.queue == 0 {
            return Err(Error::Config("rollout.queue must be > 0".into()));
        }
        if self.rollout.poll_ms == 0 {
            return Err(Error::Config("rollout.poll_ms must be > 0".into()));
        }
        self.hardware.acim.array.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        AppConfig::default().validate().unwrap();
    }

    #[test]
    fn partial_json_fills_defaults() {
        let mut cfg = AppConfig::default();
        cfg.apply(&Value::parse(r#"{"server": {"max_batch": 8}}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.server.max_batch, 8);
        assert_eq!(cfg.server.workers, ServerConfig::default().workers);
        assert_eq!(cfg.artifacts.model, "kan1");
    }

    #[test]
    fn nested_hardware_overrides() {
        let mut cfg = AppConfig::default();
        cfg.apply(
            &Value::parse(
                r#"{"hardware": {"acim": {"array": {"rows": 512}, "irdrop": false},
                    "tech": {"vdd": 0.9}}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.hardware.acim.array.rows, 512);
        assert!(!cfg.hardware.acim.irdrop);
        assert_eq!(cfg.hardware.tech.vdd, 0.9);
    }

    #[test]
    fn server_wire_limits_parse_and_validate() {
        let mut cfg = AppConfig::default();
        cfg.apply(
            &Value::parse(
                r#"{"server": {"max_request_bytes": 4096, "max_in_flight": 8}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.server.max_request_bytes, 4096);
        assert_eq!(cfg.server.max_in_flight, 8);
        cfg.validate().unwrap();

        cfg.server.max_request_bytes = 0;
        assert!(cfg.validate().is_err());
        cfg.server.max_request_bytes = 4096;
        cfg.server.max_in_flight = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn scheduler_section_parses_and_validates() {
        let mut cfg = AppConfig::default();
        assert_eq!(cfg.scheduler.policy, "fifo"); // seed behavior by default
        cfg.apply(
            &Value::parse(
                r#"{"scheduler": {"policy": "drr", "quota": 16, "fairness_window": 4}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.scheduler.policy, "drr");
        assert_eq!(cfg.scheduler.quota, 16);
        assert_eq!(cfg.scheduler.fairness_window, 4);
        cfg.validate().unwrap();

        cfg.scheduler.policy = "wfq".into();
        assert!(cfg.validate().is_err());
        cfg.scheduler.policy = "drr".into();
        cfg.scheduler.quota = 0;
        assert!(cfg.validate().is_err());
        cfg.scheduler.quota = 16;
        cfg.scheduler.fairness_window = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn bad_backend_rejected_at_parse() {
        let mut cfg = AppConfig::default();
        let err = cfg
            .apply(&Value::parse(r#"{"server": {"backend": "gpu"}}"#).unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("unknown backend 'gpu'"), "{err}");
        // a valid name parses into the typed kind
        cfg.apply(&Value::parse(r#"{"server": {"backend": "acim"}}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.server.backend, BackendKind::Acim);
    }

    #[test]
    fn shadow_section_parses_and_validates() {
        let mut cfg = AppConfig::default();
        assert!(cfg.server.shadow.backend.is_none(), "shadow off by default");
        cfg.apply(
            &Value::parse(
                r#"{"server": {"shadow": {"backend": "acim", "fraction": 0.25,
                    "queue": 32}}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.server.shadow.backend, Some(BackendKind::Acim));
        assert_eq!(cfg.server.shadow.fraction, 0.25);
        assert_eq!(cfg.server.shadow.queue, 32);
        cfg.validate().unwrap();

        // mirroring the primary backend is a config error
        cfg.server.shadow.backend = Some(cfg.server.backend);
        assert!(cfg.validate().is_err());
        cfg.server.shadow.backend = Some(BackendKind::Acim);
        cfg.server.shadow.fraction = 0.0;
        assert!(cfg.validate().is_err());
        cfg.server.shadow.fraction = 1.5;
        assert!(cfg.validate().is_err());
        cfg.server.shadow.fraction = 1.0;
        cfg.server.shadow.queue = 0;
        assert!(cfg.validate().is_err());
        // an unknown shadow backend fails the load
        let mut cfg = AppConfig::default();
        assert!(cfg
            .apply(
                &Value::parse(r#"{"server": {"shadow": {"backend": "tpu"}}}"#).unwrap()
            )
            .is_err());
    }

    #[test]
    fn registry_section_parses() {
        let mut cfg = AppConfig::default();
        cfg.apply(
            &Value::parse(
                r#"{"registry": {"max_loaded": 2, "reload_poll_ms": 250,
                    "preload": ["kan1", "kan2"], "store_dir": "objects-cache"}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.registry.max_loaded, 2);
        assert_eq!(cfg.registry.reload_poll_ms, 250);
        assert_eq!(cfg.registry.preload, vec!["kan1", "kan2"]);
        assert_eq!(cfg.registry.store_dir, "objects-cache");
        cfg.validate().unwrap();

        cfg.registry.max_loaded = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn observability_section_parses_and_validates() {
        let mut cfg = AppConfig::default();
        // defaults: sampled tracing on, profiling off
        assert_eq!(cfg.observability.sample_every, 16);
        assert_eq!(cfg.observability.trace_ring, 256);
        assert!(!cfg.observability.engine_profiling);
        assert_eq!(cfg.observability.log_level, "info");
        cfg.apply(
            &Value::parse(
                r#"{"observability": {"sample_every": 1, "trace_ring": 64,
                    "engine_profiling": true, "log_level": "debug"}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.observability.sample_every, 1);
        assert_eq!(cfg.observability.trace_ring, 64);
        assert!(cfg.observability.engine_profiling);
        assert_eq!(cfg.observability.log_level, "debug");
        cfg.validate().unwrap();

        // sample_every = 0 is valid (tracing off), ring 0 is not
        cfg.observability.sample_every = 0;
        cfg.validate().unwrap();
        cfg.observability.trace_ring = 0;
        assert!(cfg.validate().is_err());
        cfg.observability.trace_ring = 64;
        cfg.observability.log_level = "verbose".into();
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("observability.log_level"), "{err}");
    }

    #[test]
    fn cluster_section_parses_and_validates() {
        let mut cfg = AppConfig::default();
        assert!(cfg.cluster.nodes.is_empty(), "no cluster by default");
        assert_eq!(cfg.cluster.replication, 2);
        assert!(cfg.cluster.hedge);
        cfg.apply(
            &Value::parse(
                r#"{"cluster": {"nodes": ["127.0.0.1:7001", "127.0.0.1:7002"],
                    "replication": 1, "vnodes": 16, "heartbeat_ms": 100,
                    "fail_after": 3, "hedge": false, "hedge_quantile": 0.99,
                    "hedge_min_ms": 2, "hedge_max_ms": 50}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.cluster.nodes, vec!["127.0.0.1:7001", "127.0.0.1:7002"]);
        assert_eq!(cfg.cluster.replication, 1);
        assert_eq!(cfg.cluster.vnodes, 16);
        assert_eq!(cfg.cluster.heartbeat_ms, 100);
        assert_eq!(cfg.cluster.fail_after, 3);
        assert!(!cfg.cluster.hedge);
        assert_eq!(cfg.cluster.hedge_quantile, 0.99);
        assert_eq!(cfg.cluster.hedge_min_ms, 2);
        assert_eq!(cfg.cluster.hedge_max_ms, 50);
        cfg.validate().unwrap();

        cfg.cluster.replication = 0;
        assert!(cfg.validate().is_err());
        cfg.cluster.replication = 2;
        cfg.cluster.vnodes = 0;
        assert!(cfg.validate().is_err());
        cfg.cluster.vnodes = 16;
        cfg.cluster.hedge_quantile = 0.0;
        assert!(cfg.validate().is_err());
        cfg.cluster.hedge_quantile = 1.5;
        assert!(cfg.validate().is_err());
        cfg.cluster.hedge_quantile = 0.9;
        cfg.cluster.hedge_min_ms = 200;
        cfg.cluster.hedge_max_ms = 100;
        assert!(cfg.validate().is_err());
        cfg.cluster.hedge_min_ms = 1;
        cfg.cluster.fail_after = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rollout_section_parses_and_validates() {
        let mut cfg = AppConfig::default();
        assert_eq!(cfg.rollout.ramp, vec![0.05, 0.25, 0.5]);
        assert_eq!(cfg.rollout.window_ms, 2000);
        cfg.apply(
            &Value::parse(
                r#"{"rollout": {"ramp": [0.1, 0.5], "window_ms": 150,
                    "min_samples": 10, "max_flip_rate": 0.02,
                    "max_logit_mae_p99": 0.1, "max_latency_regression": 2.0,
                    "queue": 64, "poll_ms": 20}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.rollout.ramp, vec![0.1, 0.5]);
        assert_eq!(cfg.rollout.window_ms, 150);
        assert_eq!(cfg.rollout.min_samples, 10);
        assert_eq!(cfg.rollout.max_flip_rate, 0.02);
        assert_eq!(cfg.rollout.max_logit_mae_p99, 0.1);
        assert_eq!(cfg.rollout.max_latency_regression, 2.0);
        assert_eq!(cfg.rollout.queue, 64);
        assert_eq!(cfg.rollout.poll_ms, 20);
        cfg.validate().unwrap();

        // an empty ramp is valid: the rollout goes straight to Observing
        cfg.rollout.ramp = Vec::new();
        cfg.validate().unwrap();
        // fraction 0.0 is valid (baseline-only split, used by bench-net)
        cfg.rollout.ramp = vec![0.0];
        cfg.validate().unwrap();
        cfg.rollout.ramp = vec![0.5, 0.25];
        assert!(cfg.validate().is_err(), "decreasing ramp rejected");
        cfg.rollout.ramp = vec![1.5];
        assert!(cfg.validate().is_err());
        cfg.rollout.ramp = vec![0.5];
        cfg.rollout.window_ms = 0;
        assert!(cfg.validate().is_err());
        cfg.rollout.window_ms = 100;
        cfg.rollout.min_samples = 0;
        assert!(cfg.validate().is_err());
        cfg.rollout.min_samples = 1;
        cfg.rollout.max_flip_rate = 1.5;
        assert!(cfg.validate().is_err());
        cfg.rollout.max_flip_rate = 0.01;
        cfg.rollout.max_latency_regression = 0.5;
        assert!(cfg.validate().is_err());
        cfg.rollout.max_latency_regression = 1.5;
        cfg.rollout.queue = 0;
        assert!(cfg.validate().is_err());
        cfg.rollout.queue = 16;
        cfg.rollout.poll_ms = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn neurosim_constraints_parse() {
        let mut cfg = AppConfig::default();
        cfg.apply(
            &Value::parse(
                r#"{"neurosim": {"constraints": {"max_area_mm2": 0.05}, "tm_modes": [3]}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.neurosim.constraints.max_area_mm2, Some(0.05));
        assert_eq!(cfg.neurosim.tm_modes, vec![3]);
    }
}
