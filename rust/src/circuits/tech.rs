//! 22 nm technology parameters for the analytic component models.
//!
//! The paper evaluates its circuits in TSMC 22 nm with SPICE; we replace
//! SPICE with behavioural models whose constants are set to representative
//! 22 nm values (std-cell NAND2 ≈ 0.15 µm², 6T SRAM bitcell ≈ 0.1 µm²,
//! 1T1R RRAM cell ≈ 0.05 µm², V_DD = 0.8 V). The Fig 10/11/13 comparisons
//! depend on *structure* (what scales exponentially, what is static power,
//! what stacks in series), which these models capture; the constants set
//! the absolute scale. See DESIGN.md §4 (substitutions).


/// Process/voltage constants shared by every component model.
#[derive(Debug, Clone, Copy)]
pub struct Tech {
    /// Supply voltage (V).
    pub vdd: f64,
    /// NAND2-equivalent gate area (µm²).
    pub gate_area_um2: f64,
    /// Energy per gate switching event (fJ).
    pub gate_energy_fj: f64,
    /// 6T SRAM bitcell area including periphery share (µm²/bit).
    pub sram_bit_area_um2: f64,
    /// SRAM read energy (fJ/bit).
    pub sram_read_fj_per_bit: f64,
    /// SRAM/LUT bit-line precharge energy per stored entry per access (fJ).
    pub lut_precharge_fj_per_entry: f64,
    /// Transmission gate area (µm², 2 transistors).
    pub tg_area_um2: f64,
    /// TG switching energy (fJ).
    pub tg_energy_fj: f64,
    /// 1T1R RRAM cell area (µm²).
    pub rram_cell_area_um2: f64,
    /// Unit pulse width for WL input generation (ns).
    pub unit_pulse_ns: f64,
    /// DAC resistor-string unit cell area (µm² per level).
    pub dac_unit_area_um2: f64,
    /// DAC bias/output-buffer fixed area (µm²).
    pub dac_fixed_area_um2: f64,
    /// DAC static power coefficient (µW per level·bit) — higher resolution
    /// needs both more taps (2^N) and tighter settling (∝ N).
    pub dac_static_uw_per_level_bit: f64,
    /// Delay-chain stage area (µm²; 2 inverters + tap/select logic).
    pub delay_stage_area_um2: f64,
    /// Delay-chain power per stage (µW) — the chain free-runs as the
    /// timing reference in read mode, so this is a continuous draw.
    pub delay_stage_power_uw: f64,
    /// PM-TCM (pulse-modulation timing control) area (µm²).
    pub pm_tcm_area_um2: f64,
    /// PM-TCM power (µW).
    pub pm_tcm_power_uw: f64,
    /// WL driver buffer area (µm²).
    pub buffer_area_um2: f64,
    /// WL driver buffer power while driving (µW).
    pub buffer_power_uw: f64,
    /// Sense-amplifier / column ADC area (µm², per converter).
    pub adc_area_um2: f64,
    /// ADC energy per conversion (fJ).
    pub adc_energy_fj: f64,
    /// ADC conversion time (ns).
    pub adc_time_ns: f64,
    /// Column mux sharing ratio (columns per ADC).
    pub adc_share: usize,
    /// Routing/interconnect area overhead multiplier on raw cell area.
    pub routing_factor: f64,
}

impl Default for Tech {
    fn default() -> Self {
        Self {
            vdd: 0.8,
            gate_area_um2: 0.15,
            gate_energy_fj: 0.06,
            sram_bit_area_um2: 0.10,
            sram_read_fj_per_bit: 0.5,
            lut_precharge_fj_per_entry: 0.05,
            tg_area_um2: 0.06,
            tg_energy_fj: 0.02,
            rram_cell_area_um2: 0.05,
            unit_pulse_ns: 0.5,
            dac_unit_area_um2: 0.75,
            dac_fixed_area_um2: 18.0,
            dac_static_uw_per_level_bit: 0.48,
            delay_stage_area_um2: 0.46,
            delay_stage_power_uw: 0.1,
            pm_tcm_area_um2: 6.5,
            pm_tcm_power_uw: 0.8,
            buffer_area_um2: 4.0,
            buffer_power_uw: 1.5,
            adc_area_um2: 180.0,
            adc_energy_fj: 180.0,
            adc_time_ns: 8.0,
            adc_share: 8,
            routing_factor: 1.6,
        }
    }
}

/// Area (µm²), energy per operation (fJ), latency (ns) triple — the unit
/// every component model reports in.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Cost {
    pub area_um2: f64,
    pub energy_fj: f64,
    pub latency_ns: f64,
}

impl Cost {
    pub fn new(area_um2: f64, energy_fj: f64, latency_ns: f64) -> Self {
        Self { area_um2, energy_fj, latency_ns }
    }

    /// Sum areas and energies; latency takes the max (parallel composition).
    pub fn parallel(self, other: Cost) -> Cost {
        Cost {
            area_um2: self.area_um2 + other.area_um2,
            energy_fj: self.energy_fj + other.energy_fj,
            latency_ns: self.latency_ns.max(other.latency_ns),
        }
    }

    /// Sum everything (series composition).
    pub fn series(self, other: Cost) -> Cost {
        Cost {
            area_um2: self.area_um2 + other.area_um2,
            energy_fj: self.energy_fj + other.energy_fj,
            latency_ns: self.latency_ns + other.latency_ns,
        }
    }

    /// Replicate a component `n` times operating in parallel.
    pub fn replicate(self, n: usize) -> Cost {
        Cost {
            area_um2: self.area_um2 * n as f64,
            energy_fj: self.energy_fj * n as f64,
            latency_ns: self.latency_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_composition() {
        let a = Cost::new(1.0, 2.0, 3.0);
        let b = Cost::new(10.0, 20.0, 1.0);
        let s = a.series(b);
        assert_eq!(s, Cost::new(11.0, 22.0, 4.0));
        let p = a.parallel(b);
        assert_eq!(p, Cost::new(11.0, 22.0, 3.0));
        let r = a.replicate(4);
        assert_eq!(r, Cost::new(4.0, 8.0, 3.0));
    }

    #[test]
    fn defaults_are_sane() {
        let t = Tech::default();
        assert!(t.vdd > 0.0 && t.vdd < 2.0);
        assert!(t.rram_cell_area_um2 < t.sram_bit_area_um2);
    }
}
