//! Analytic 22 nm component models: decoders, LUTs, MUX/DEMUX trees, DACs,
//! delay chains, control logic, buffers, ADCs.
//!
//! Each component reports a [`Cost`] = (area µm², energy fJ *per
//! operation*, latency ns). The structural scaling laws are the load-bearing
//! part: decoder area/energy grow exponentially with bit width (the fact
//! PowerGap exploits), LUT cost scales with stored entries (the fact
//! Alignment-Symmetry exploits), DAC static power grows steeply with
//! resolution (the fact TM-DV-IG exploits).

use super::tech::{Cost, Tech};

/// An n-bit one-hot decoder (row decoder style: predecode + 2^n AND gates).
#[derive(Debug, Clone, Copy)]
pub struct Decoder {
    pub bits: u32,
}

impl Decoder {
    pub fn new(bits: u32) -> Self {
        Self { bits }
    }

    pub fn cost(&self, t: &Tech) -> Cost {
        if self.bits == 0 {
            return Cost::default();
        }
        let lines = (1u64 << self.bits) as f64;
        let b = self.bits as f64;
        // predecoders (~2b gates) + one (b/2)-input AND per output line
        let area = (2.0 * b + lines * (b / 2.0).max(1.0)) * t.gate_area_um2;
        // per access: predecode switching + one line toggles + wire load
        // that grows with the number of lines it crosses
        let energy = (4.0 * b + 0.05 * lines) * t.gate_energy_fj;
        let latency = 0.02 * b; // ns, logarithmic depth ~ b levels
        Cost::new(area, energy, latency)
    }
}

/// An SRAM-backed LUT holding `entries` words of `word_bits` bits.
/// Non-programmable (ROM/hardwired) variants are ~3x denser but lose the
/// flexibility the paper insists on keeping (§2.1).
#[derive(Debug, Clone, Copy)]
pub struct Lut {
    pub entries: usize,
    pub word_bits: u32,
    pub programmable: bool,
}

impl Lut {
    pub fn programmable(entries: usize, word_bits: u32) -> Self {
        Self { entries, word_bits, programmable: true }
    }

    pub fn fixed(entries: usize, word_bits: u32) -> Self {
        Self { entries, word_bits, programmable: false }
    }

    pub fn bits(&self) -> f64 {
        self.entries as f64 * self.word_bits as f64
    }

    /// Cost of storing the table and reading `words_per_access` words.
    /// Every access also precharges the whole array (∝ stored entries) —
    /// the term that makes many small per-basis LUTs expensive (Fig 10).
    pub fn cost(&self, t: &Tech, words_per_access: usize) -> Cost {
        let density = if self.programmable { 1.0 } else { 1.0 / 3.0 };
        let area = self.bits() * t.sram_bit_area_um2 * density;
        let energy = words_per_access as f64
            * self.word_bits as f64
            * t.sram_read_fj_per_bit
            + self.entries as f64 * t.lut_precharge_fj_per_entry;
        Cost::new(area, energy, 0.15)
    }
}

/// A `ways`-to-1 transmission-gate MUX (tree of TGs).
#[derive(Debug, Clone, Copy)]
pub struct TgMux {
    pub ways: usize,
}

impl TgMux {
    pub fn new(ways: usize) -> Self {
        Self { ways }
    }

    pub fn cost(&self, t: &Tech) -> Cost {
        if self.ways <= 1 {
            return Cost::default();
        }
        let levels = (self.ways as f64).log2().ceil().max(1.0);
        // a TG tree needs ~ways TGs total; the active path switches `levels`
        let area = self.ways as f64 * t.tg_area_um2;
        let energy = levels * t.tg_energy_fj;
        Cost::new(area, energy, 0.01 * levels)
    }
}

/// A 1-to-`ways` TG DEMUX (same tree, driven the other way).
#[derive(Debug, Clone, Copy)]
pub struct TgDemux {
    pub ways: usize,
}

impl TgDemux {
    pub fn new(ways: usize) -> Self {
        Self { ways }
    }

    pub fn cost(&self, t: &Tech) -> Cost {
        TgMux { ways: self.ways }.cost(t)
    }
}

/// An N-bit resistor-string DAC with output buffer.
///
/// Static power is the defining property: the string conducts continuously
/// in read mode, and higher resolution needs both more taps (2^N) and
/// tighter settling (∝ N), so `P_static ∝ N·2^N` — the reason the pure
/// 6-bit voltage input generator burns 11.9x the power of TM-DV-IG (Fig 11).
#[derive(Debug, Clone, Copy)]
pub struct Dac {
    pub bits: u32,
}

impl Dac {
    pub fn new(bits: u32) -> Self {
        Self { bits }
    }

    pub fn levels(&self) -> usize {
        1usize << self.bits
    }

    pub fn area_um2(&self, t: &Tech) -> f64 {
        self.levels() as f64 * t.dac_unit_area_um2 + t.dac_fixed_area_um2
    }

    pub fn static_power_uw(&self, t: &Tech) -> f64 {
        self.levels() as f64 * self.bits as f64 * t.dac_static_uw_per_level_bit
    }

    /// Cost for one conversion held for `duration_ns`.
    pub fn cost(&self, t: &Tech, duration_ns: f64) -> Cost {
        let energy = self.static_power_uw(t) * duration_ns; // µW·ns = fJ
        Cost::new(self.area_um2(t), energy, 0.2 * self.bits as f64)
    }
}

/// A delay chain of `stages` buffered taps (pulse-width generation).
#[derive(Debug, Clone, Copy)]
pub struct DelayChain {
    pub stages: usize,
}

impl DelayChain {
    pub fn new(stages: usize) -> Self {
        Self { stages }
    }

    pub fn area_um2(&self, t: &Tech) -> f64 {
        self.stages as f64 * t.delay_stage_area_um2
    }

    /// Cost of producing one pulse of `pulse_stages` unit widths.
    pub fn cost(&self, t: &Tech, pulse_stages: usize, unit_ns: f64) -> Cost {
        let active = pulse_stages.min(self.stages) as f64;
        // dynamic power of the toggling stages over the pulse duration
        let energy = active * t.delay_stage_power_uw * unit_ns;
        Cost::new(self.area_um2(t), energy, active * unit_ns)
    }
}

/// Pulse-modulation timing control (PM-TCM of Fig 7).
#[derive(Debug, Clone, Copy)]
pub struct PmTcm;

impl PmTcm {
    pub fn cost(&self, t: &Tech, duration_ns: f64) -> Cost {
        Cost::new(t.pm_tcm_area_um2, t.pm_tcm_power_uw * duration_ns, 0.05)
    }
}

/// WL driver buffer (one per word line; the TM-DV-IG switches its supply).
#[derive(Debug, Clone, Copy)]
pub struct WlBuffer;

impl WlBuffer {
    pub fn cost(&self, t: &Tech, duration_ns: f64) -> Cost {
        Cost::new(t.buffer_area_um2, t.buffer_power_uw * duration_ns, 0.05)
    }
}

/// Column ADC / sense amplifier (shared across `t.adc_share` columns).
#[derive(Debug, Clone, Copy)]
pub struct ColumnAdc;

impl ColumnAdc {
    /// Cost of converting `cols` columns (time-multiplexed by `adc_share`).
    pub fn cost(&self, t: &Tech, cols: usize) -> Cost {
        let converters = cols.div_ceil(t.adc_share);
        let rounds = cols.div_ceil(converters.max(1));
        Cost::new(
            converters as f64 * t.adc_area_um2,
            cols as f64 * t.adc_energy_fj,
            rounds as f64 * t.adc_time_ns,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Tech {
        Tech::default()
    }

    #[test]
    fn decoder_area_grows_exponentially() {
        let t = t();
        let a8 = Decoder::new(8).cost(&t).area_um2;
        let a5 = Decoder::new(5).cost(&t).area_um2;
        let a3 = Decoder::new(3).cost(&t).area_um2;
        // splitting one 8-bit decoder into 5+3 must be much cheaper (PowerGap)
        assert!(a5 + a3 < a8 / 4.0, "split {} vs mono {}", a5 + a3, a8);
        assert_eq!(Decoder::new(0).cost(&t).area_um2, 0.0);
    }

    #[test]
    fn lut_fixed_is_denser_but_smaller_story() {
        let t = t();
        let p = Lut::programmable(128, 8).cost(&t, 1);
        let f = Lut::fixed(128, 8).cost(&t, 1);
        assert!(f.area_um2 < p.area_um2 / 2.0);
        assert_eq!(f.energy_fj, p.energy_fj); // reads cost the same
    }

    #[test]
    fn dac_static_power_superlinear_in_bits() {
        let t = t();
        let p6 = Dac::new(6).static_power_uw(&t);
        let p3 = Dac::new(3).static_power_uw(&t);
        assert!(p6 / p3 > 8.0, "ratio {}", p6 / p3); // 2^3 from taps x2 from N
    }

    #[test]
    fn delay_chain_latency_linear_in_pulse() {
        let t = t();
        let c = DelayChain::new(64);
        assert_eq!(c.cost(&t, 64, 1.0).latency_ns, 64.0);
        assert_eq!(c.cost(&t, 8, 1.0).latency_ns, 8.0);
        // pulse longer than the chain saturates
        assert_eq!(c.cost(&t, 100, 1.0).latency_ns, 64.0);
    }

    #[test]
    fn adc_sharing_reduces_area_not_energy() {
        let t = t();
        let shared = ColumnAdc.cost(&t, 64);
        assert_eq!(shared.area_um2, (64f64 / 8.0).ceil() * t.adc_area_um2);
        assert_eq!(shared.energy_fj, 64.0 * t.adc_energy_fj);
        assert!(shared.latency_ns >= 8.0 * 0.999 * t.adc_time_ns);
    }

    #[test]
    fn mux_tree_scales_with_ways() {
        let t = t();
        let m64 = TgMux::new(64).cost(&t);
        let m8 = TgMux::new(8).cost(&t);
        assert!(m64.area_um2 > 7.0 * m8.area_um2 / 1.01);
        assert_eq!(TgMux::new(1).cost(&t).area_um2, 0.0);
    }
}
