//! The B(X) retrieval path (input code → LUT → routing → input generator):
//! the hardware Fig 10 compares between conventional quantization and
//! ASP-KAN-HAQ.
//!
//! Three design points are modelled:
//!
//! * [`BxPathDesign::Conventional`] — PACT-style quantization: grids
//!   misaligned, so each of the `G+K` basis functions carries its own
//!   programmable LUT (over its support), its own `2L:1` TG-MUX, and its
//!   own n-bit decoder (paper §2.1: "individual LUTs, MUXs, and decoders
//!   for each Bi(x)").
//! * [`BxPathDesign::AlignmentOnly`] — ASP phase 1 only: one shared SH-LUT,
//!   but routing still needs `K+1` wide `2L:1` TG-MUXes and a full n-bit
//!   decoder (the "straightforward approach" of §3.1-A).
//! * [`BxPathDesign::AspFull`] — phase 1 + 2 (PowerGap): SH-LUT plus
//!   `K+1` `L/2:1` MUXes, `K+1` `1:G` DEMUXes, and an (n−D)-bit + D-bit
//!   decoder pair (§3.1-B, Fig 5).


use super::components::{Decoder, Lut, TgDemux, TgMux};
use super::tech::{Cost, Tech};
use crate::error::Result;
use crate::quant::{solve_ld, AspSpec, PactSpec, ShLut};

/// Which B(X)-path hardware design to cost out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BxPathDesign {
    Conventional,
    AlignmentOnly,
    AspFull,
}

/// Itemized cost report of one B(X) retrieval path design point.
#[derive(Debug, Clone)]
pub struct BxPathReport {
    pub design: BxPathDesign,
    pub g: u32,
    pub k: u32,
    pub n_bits: u32,
    pub lut: Cost,
    pub mux: Cost,
    pub decoder: Cost,
    pub total: Cost,
    /// Stored LUT bits (flexibility metric).
    pub lut_bits: f64,
}

/// Cost one lookup (all active basis values for one input X) through the
/// chosen design.
pub fn cost_bx_path(
    design: BxPathDesign,
    g: u32,
    k: u32,
    n_bits: u32,
    t: &Tech,
) -> Result<BxPathReport> {
    let nb = (g + k) as usize;
    let report = match design {
        BxPathDesign::Conventional => {
            let pact = PactSpec::new(g, k, n_bits, 0.0, 1.0);
            let entries = pact.per_basis_lut_entries();
            // per basis: its own programmable LUT over its support, a
            // right-sized (log2 entries)-bit decoder, and an entries:1
            // TG-MUX; one shared n-bit decoder resolves the segment. Every
            // LUT precharges each cycle (clocked arrays), but only the K+1
            // active bases read a word out.
            let local_bits = (entries as f64).log2().ceil() as u32;
            let lut_model = Lut::programmable(entries, n_bits);
            let lut = Cost::new(
                lut_model.cost(t, 0).area_um2 * nb as f64,
                // nb precharges + K+1 word reads
                nb as f64 * entries as f64 * t.lut_precharge_fj_per_entry
                    + (k + 1) as f64 * n_bits as f64 * t.sram_read_fj_per_bit,
                0.15,
            );
            let mux_one = TgMux::new(entries).cost(t);
            let mux = Cost::new(
                mux_one.area_um2 * nb as f64,
                mux_one.energy_fj * (k + 1) as f64,
                mux_one.latency_ns,
            );
            let dec_one = Decoder::new(local_bits).cost(t);
            let decoder = dec_one
                .replicate(nb)
                .parallel(Decoder::new(n_bits).cost(t));
            let total = lut.parallel(mux).parallel(decoder);
            BxPathReport {
                design,
                g,
                k,
                n_bits,
                lut,
                mux,
                decoder,
                total,
                lut_bits: lut_model.bits() * nb as f64,
            }
        }
        BxPathDesign::AlignmentOnly => {
            let spec = AspSpec::build(g, k, n_bits, 0.0, 1.0)?;
            let sh = ShLut::build(&spec, n_bits);
            let l = spec.levels_per_interval() as usize;
            // one shared hemi LUT, read K+1 words per lookup
            let lut_c = Lut::programmable(sh.stored_entries(), n_bits).cost(t, k as usize + 1);
            // K+1 wide 2L:1 TG-MUXes route hemi rows to the active bases
            let mux = TgMux::new(2 * l).cost(t).replicate(k as usize + 1);
            let decoder = Decoder::new(n_bits).cost(t);
            let total = lut_c.parallel(mux).parallel(decoder);
            BxPathReport {
                design,
                g,
                k,
                n_bits,
                lut: lut_c,
                mux,
                decoder,
                total,
                lut_bits: sh.stored_entries() as f64 * n_bits as f64,
            }
        }
        BxPathDesign::AspFull => {
            let spec = AspSpec::build(g, k, n_bits, 0.0, 1.0)?;
            let sh = ShLut::build(&spec, n_bits);
            let ld = solve_ld(g, n_bits)?;
            let l = spec.levels_per_interval() as usize;
            let lut_c = Lut::programmable(sh.stored_entries(), n_bits).cost(t, k as usize + 1);
            // K+1 of: L/2:1 MUX (hemi row select) + 1:G DEMUX (global route)
            let mux = TgMux::new((l / 2).max(1))
                .cost(t)
                .parallel(TgDemux::new(g as usize).cost(t))
                .replicate(k as usize + 1);
            // decoder split: (n-D)-bit global + D-bit local
            let decoder = Decoder::new(n_bits - ld)
                .cost(t)
                .parallel(Decoder::new(ld).cost(t));
            let total = lut_c.parallel(mux).parallel(decoder);
            BxPathReport {
                design,
                g,
                k,
                n_bits,
                lut: lut_c,
                mux,
                decoder,
                total,
                lut_bits: sh.stored_entries() as f64 * n_bits as f64,
            }
        }
    };
    Ok(report)
}

/// One row of the Fig 10 sweep: conventional vs ASP for a given G.
#[derive(Debug, Clone)]
pub struct Fig10Row {
    pub g: u32,
    pub conventional: BxPathReport,
    pub asp: BxPathReport,
    pub area_reduction: f64,
    pub energy_reduction: f64,
}

/// Run the paper's Fig 10 sweep (G = 8..64 by powers of two, K = 3, 8-bit).
pub fn fig10_sweep(gs: &[u32], k: u32, n_bits: u32, t: &Tech) -> Result<Vec<Fig10Row>> {
    gs.iter()
        .map(|&g| {
            let conv = cost_bx_path(BxPathDesign::Conventional, g, k, n_bits, t)?;
            let asp = cost_bx_path(BxPathDesign::AspFull, g, k, n_bits, t)?;
            Ok(Fig10Row {
                g,
                area_reduction: conv.total.area_um2 / asp.total.area_um2,
                energy_reduction: conv.total.energy_fj / asp.total.energy_fj,
                conventional: conv,
                asp,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sweep() -> Vec<Fig10Row> {
        fig10_sweep(&[8, 16, 32, 64], 3, 8, &Tech::default()).unwrap()
    }

    #[test]
    fn asp_always_wins() {
        for row in sweep() {
            assert!(row.area_reduction > 1.0, "G={}", row.g);
            assert!(row.energy_reduction > 1.0, "G={}", row.g);
        }
    }

    #[test]
    fn fig10_average_reductions_in_paper_band() {
        // paper: average 40.14x area, 5.59x energy over G = 8..64.
        // behavioural models, so we assert a generous band around those.
        let rows = sweep();
        let avg_area: f64 =
            rows.iter().map(|r| r.area_reduction).sum::<f64>() / rows.len() as f64;
        let avg_energy: f64 =
            rows.iter().map(|r| r.energy_reduction).sum::<f64>() / rows.len() as f64;
        assert!(
            (20.0..80.0).contains(&avg_area),
            "avg area reduction {avg_area:.2} outside band (paper 40.14x)"
        );
        assert!(
            (3.0..11.0).contains(&avg_energy),
            "avg energy reduction {avg_energy:.2} outside band (paper 5.59x)"
        );
    }

    #[test]
    fn phase2_beats_phase1_alone() {
        let t = Tech::default();
        for g in [8u32, 16, 32, 64] {
            let p1 = cost_bx_path(BxPathDesign::AlignmentOnly, g, 3, 8, &t).unwrap();
            let p2 = cost_bx_path(BxPathDesign::AspFull, g, 3, 8, &t).unwrap();
            assert!(
                p2.total.area_um2 < p1.total.area_um2,
                "G={g}: PowerGap did not reduce area"
            );
            // the decoder split is the dominant phase-2 win
            assert!(p2.decoder.area_um2 < p1.decoder.area_um2 / 2.0);
        }
    }

    #[test]
    fn shared_lut_bits_shrink_vs_conventional() {
        for row in sweep() {
            assert!(
                row.asp.lut_bits < row.conventional.lut_bits / 4.0,
                "G={}: {} vs {}",
                row.g,
                row.asp.lut_bits,
                row.conventional.lut_bits
            );
        }
    }
}
