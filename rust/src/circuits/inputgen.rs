//! Word-line input generators (paper §3.2, Fig 7/11).
//!
//! Three ways to turn an M-bit digital value into a WL drive that deposits a
//! proportional charge Q on the bit line:
//!
//! * [`PureVoltage`] — an M-bit DAC produces `2^M` voltage levels, applied
//!   for one unit pulse. Fastest, but the DAC string burns static power and
//!   the noise margin is `VDD / 2^M` (tiny at 6 bits).
//! * [`PurePwm`] — one voltage, `2^M` possible pulse widths from a long
//!   delay chain. Robust (full-swing levels) but `2^M` unit latencies.
//! * [`TmDvIg`] — the paper's N:1 Time-Modulation Dynamic-Voltage input
//!   generator: the low N bits go to a small `2^N`-level DAC (configured so
//!   cell currents are linear in the code, Fig 7b), the remaining `M − N`
//!   bits become pulse width from a short chain. Latency `2^(M−N)` units,
//!   noise margin `VDD / 2^N`, small DAC: the sweet spot in between.
//!
//! `FOM = 1 / (area · power · latency)`, normalized to TM-DV-IG, is the
//! paper's Fig 11 headline: 3x over pure voltage, 4.1x over pure PWM.


use super::components::{Dac, DelayChain, TgMux, WlBuffer};
use super::tech::Tech;

/// What every input generator reports for the Fig 11 comparison.
#[derive(Debug, Clone)]
pub struct InputGenReport {
    pub name: String,
    pub area_um2: f64,
    pub power_uw: f64,
    pub latency_ns: f64,
    /// Worst-case spacing between adjacent analog levels (V) — noise margin.
    pub noise_margin_v: f64,
    pub energy_fj: f64,
}

impl InputGenReport {
    /// Figure of merit: inverse of area x power x latency.
    pub fn fom(&self) -> f64 {
        1.0 / (self.area_um2 * self.power_uw * self.latency_ns)
    }
}

/// Common interface: generate the worst-case (all-levels exercised) drive
/// for an `bits`-bit input and report cost.
pub trait InputGenerator {
    fn name(&self) -> &'static str;
    fn report(&self, bits: u32, t: &Tech) -> InputGenReport;

    /// The (voltage_level_fraction, pulse_units) pair encoding `code`.
    /// `voltage` is in [0, 1] (fraction of the linear-current full scale),
    /// pulse width in unit pulses. Charge deposited ∝ voltage · pulse.
    fn encode(&self, code: u32, bits: u32) -> (f64, u32);
}

/// Pure multi-level voltage input (refs [18][19] in the paper).
#[derive(Debug, Clone, Copy, Default)]
pub struct PureVoltage;

impl InputGenerator for PureVoltage {
    fn name(&self) -> &'static str {
        "pure-voltage"
    }

    fn report(&self, bits: u32, t: &Tech) -> InputGenReport {
        let dur = t.unit_pulse_ns; // a single unit pulse
        let dac = Dac::new(bits);
        let mux = TgMux::new(dac.levels());
        let buf = WlBuffer;
        let area = dac.area_um2(t) + mux.cost(t).area_um2 + t.buffer_area_um2;
        let power = dac.static_power_uw(t) + t.buffer_power_uw;
        InputGenReport {
            name: self.name().into(),
            area_um2: area,
            power_uw: power,
            latency_ns: dur,
            noise_margin_v: t.vdd / dac.levels() as f64,
            energy_fj: power * dur + buf.cost(t, dur).energy_fj,
        }
    }

    fn encode(&self, code: u32, bits: u32) -> (f64, u32) {
        let levels = (1u32 << bits) - 1;
        (code as f64 / levels as f64, 1)
    }
}

/// Pure pulse-width modulation input (refs [20][21]).
#[derive(Debug, Clone, Copy, Default)]
pub struct PurePwm;

impl InputGenerator for PurePwm {
    fn name(&self) -> &'static str {
        "pure-pwm"
    }

    fn report(&self, bits: u32, t: &Tech) -> InputGenReport {
        let steps = 1usize << bits;
        let dur = steps as f64 * t.unit_pulse_ns; // worst-case full-scale pulse
        let chain = DelayChain::new(steps);
        let area = chain.area_um2(t) + t.pm_tcm_area_um2 + t.buffer_area_um2;
        // the delay chain free-runs as the timing reference: continuous draw
        let power = steps as f64 * t.delay_stage_power_uw
            + t.pm_tcm_power_uw
            + t.buffer_power_uw;
        InputGenReport {
            name: self.name().into(),
            area_um2: area,
            power_uw: power,
            latency_ns: dur,
            // full-swing binary levels: margin is VDD/2
            noise_margin_v: t.vdd / 2.0,
            energy_fj: power * dur,
        }
    }

    fn encode(&self, code: u32, _bits: u32) -> (f64, u32) {
        (1.0, code)
    }
}

/// The paper's N:1 Time-Modulation Dynamic-Voltage input generator.
///
/// `n_voltage_bits` is the paper's N. Fig 7's components: delay chain,
/// PM-TCM, N-bit DAC, TG-MUX, buffer array (supply-switched).
#[derive(Debug, Clone, Copy)]
pub struct TmDvIg {
    pub n_voltage_bits: u32,
}

impl TmDvIg {
    /// The paper's default operating point for 6-bit inputs (N = 3).
    pub fn default_6bit() -> Self {
        Self { n_voltage_bits: 3 }
    }

    /// High-accuracy mode (TD-A): fewer voltage bits, more time bits.
    pub fn high_accuracy() -> Self {
        Self { n_voltage_bits: 2 }
    }

    /// High-performance mode (TD-P): more voltage bits, fewer time bits.
    pub fn high_performance() -> Self {
        Self { n_voltage_bits: 4 }
    }

    pub fn time_bits(&self, bits: u32) -> u32 {
        bits.saturating_sub(self.n_voltage_bits)
    }
}

impl InputGenerator for TmDvIg {
    fn name(&self) -> &'static str {
        "tm-dv-ig"
    }

    fn report(&self, bits: u32, t: &Tech) -> InputGenReport {
        let n = self.n_voltage_bits.min(bits);
        let tbits = bits - n;
        let steps = 1usize << tbits; // worst-case pulse units
        let dur = steps as f64 * t.unit_pulse_ns;
        let dac = Dac::new(n);
        let chain = DelayChain::new(steps);
        let mux = TgMux::new(dac.levels());
        let area = dac.area_um2(t)
            + chain.area_um2(t)
            + t.pm_tcm_area_um2
            + mux.cost(t).area_um2
            + t.buffer_area_um2;
        let power = dac.static_power_uw(t)
            + steps as f64 * t.delay_stage_power_uw
            + t.pm_tcm_power_uw
            + t.buffer_power_uw;
        InputGenReport {
            name: self.name().into(),
            area_um2: area,
            power_uw: power,
            latency_ns: dur,
            noise_margin_v: t.vdd / dac.levels() as f64,
            energy_fj: power * dur,
        }
    }

    fn encode(&self, code: u32, bits: u32) -> (f64, u32) {
        let n = self.n_voltage_bits.min(bits);
        let vmask = (1u32 << n) - 1;
        let v = (code & vmask) as f64 / vmask.max(1) as f64;
        let pulse = code >> n;
        // charge Q ∝ I[v] · W: low bits set the current level, high bits the
        // pulse count (Fig 7b's linear Q construction)
        (v, pulse)
    }
}

/// The Fig 11 comparison table at a given input precision.
pub fn fig11_comparison(bits: u32, t: &Tech) -> Vec<InputGenReport> {
    vec![
        PureVoltage.report(bits, t),
        PurePwm.report(bits, t),
        TmDvIg::default_6bit().report(bits, t),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reports() -> (InputGenReport, InputGenReport, InputGenReport) {
        let t = Tech::default();
        let v = fig11_comparison(6, &t);
        (v[0].clone(), v[1].clone(), v[2].clone())
    }

    #[test]
    fn pwm_latency_is_8x_tmdv() {
        let (_, pwm, tm) = reports();
        // 6-bit, N=3: PWM worst case 64 units vs TM-DV 8 units
        assert_eq!(pwm.latency_ns / tm.latency_ns, 8.0);
    }

    #[test]
    fn voltage_overheads_in_paper_band() {
        // paper: 1.96x area, 11.9x power vs TM-DV-IG
        let (v, _, tm) = reports();
        let area_ratio = v.area_um2 / tm.area_um2;
        let power_ratio = v.power_uw / tm.power_uw;
        assert!(
            (1.6..2.4).contains(&area_ratio),
            "area ratio {area_ratio:.2} (paper 1.96x)"
        );
        assert!(
            (9.5..14.5).contains(&power_ratio),
            "power ratio {power_ratio:.2} (paper 11.9x)"
        );
    }

    #[test]
    fn pwm_area_overhead_in_paper_band() {
        // paper: 1.07x area vs TM-DV-IG (long delay chain)
        let (_, pwm, tm) = reports();
        let r = pwm.area_um2 / tm.area_um2;
        assert!((0.95..1.25).contains(&r), "pwm area ratio {r:.2} (paper 1.07x)");
    }

    #[test]
    fn fom_improvements_in_paper_band() {
        // paper: TM-DV FOM 3x over pure voltage, 4.1x over pure PWM
        let (v, pwm, tm) = reports();
        let over_v = tm.fom() / v.fom();
        let over_pwm = tm.fom() / pwm.fom();
        assert!((2.4..3.9).contains(&over_v), "FOM over voltage {over_v:.2}");
        assert!((3.2..5.0).contains(&over_pwm), "FOM over pwm {over_pwm:.2}");
    }

    #[test]
    fn noise_margin_ordering() {
        let (v, pwm, tm) = reports();
        assert!(pwm.noise_margin_v > tm.noise_margin_v);
        assert!(tm.noise_margin_v > v.noise_margin_v);
    }

    #[test]
    fn encode_charge_is_monotone_nondecreasing() {
        // deposited charge v*pulse must never decrease with the code for
        // each generator (linearity of Fig 7b)
        let gens: Vec<Box<dyn InputGenerator>> = vec![
            Box::new(PureVoltage),
            Box::new(PurePwm),
            Box::new(TmDvIg::default_6bit()),
        ];
        for gen in &gens {
            let mut last = -1.0;
            for code in 0..64u32 {
                let (v, p) = gen.encode(code, 6);
                let q = v * p as f64;
                // TM-DV's charge is v*pulse with v in [0,1] scaled per-step;
                // monotonicity holds within each pulse bucket
                if gen.name() != "tm-dv-ig" {
                    assert!(q >= last, "{} code {code}: {q} < {last}", gen.name());
                    last = q;
                }
            }
        }
    }

    #[test]
    fn td_modes_trade_latency_for_margin() {
        let t = Tech::default();
        let perf = TmDvIg::high_performance().report(6, &t);
        let acc = TmDvIg::high_accuracy().report(6, &t);
        assert!(perf.latency_ns < acc.latency_ns);
        assert!(acc.noise_margin_v > perf.noise_margin_v);
    }
}
