//! Analytic 22 nm circuit models (paper's SPICE substitute; DESIGN.md §4).
//!
//! * [`tech`] — process constants and the (area, energy, latency) triple.
//! * [`components`] — decoders, LUTs, MUXes, DACs, delay chains, ADCs.
//! * [`bx_path`] — the B(X) retrieval path: ASP-KAN-HAQ vs conventional
//!   quantization (Fig 10).
//! * [`inputgen`] — pure-voltage / pure-PWM / TM-DV-IG word-line input
//!   generators and the FOM comparison (Fig 11).

pub mod bx_path;
pub mod components;
pub mod inputgen;
pub mod tech;

pub use bx_path::{cost_bx_path, fig10_sweep, BxPathDesign, BxPathReport, Fig10Row};
pub use inputgen::{
    fig11_comparison, InputGenReport, InputGenerator, PurePwm, PureVoltage, TmDvIg,
};
pub use tech::{Cost, Tech};
