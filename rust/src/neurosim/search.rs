//! Fig 9 step 1: constraint-driven design-point search.
//!
//! Candidates are (G from the training sweep, TM-DV-IG mode). Each is
//! costed with [`super::cost::estimate_kan`]; admissible candidates are
//! ranked by validated accuracy (from the sweep manifest the python build
//! path produced), ties broken by energy. The grid-extension training
//! itself (step 2) runs at build time in `python/compile/train.py` — this
//! module consumes its results, mirroring the paper's split between the
//! PyTorch environment and the NeuroSim cost engine.


use super::constraints::HwConstraints;
use super::cost::{estimate_kan, AccelReport, KanArch};
use crate::circuits::Tech;
use crate::error::Result;
use crate::kan::checkpoint::SweepEntry;

/// One evaluated candidate design point.
#[derive(Debug, Clone)]
pub struct CandidateResult {
    pub g: u32,
    pub tm_n: u32,
    pub accuracy: f64,
    pub report: AccelReport,
    pub admitted: bool,
    pub violations: Vec<String>,
}

/// Search outcome: all candidates plus the winner (if any).
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub candidates: Vec<CandidateResult>,
    pub best: Option<CandidateResult>,
}

/// Evaluate every (sweep G, TM mode) candidate against the constraints.
///
/// `dims` is the KAN architecture of the sweep models; `tm_modes` the
/// TM-DV-IG voltage-bit settings to consider (TD-A=2, default=3, TD-P=4).
pub fn search(
    dims: &[usize],
    sweep: &[SweepEntry],
    tm_modes: &[u32],
    constraints: &HwConstraints,
    tech: &Tech,
) -> Result<SearchOutcome> {
    let mut candidates = Vec::new();
    for entry in sweep {
        for &tm_n in tm_modes {
            let arch = KanArch {
                dims: dims.to_vec(),
                g: entry.g,
                k: 3,
                n_bits: 8,
                tm_n,
                array_rows: 256,
            };
            let report = estimate_kan(&arch, tech)?;
            let violations = constraints.violations(&report);
            candidates.push(CandidateResult {
                g: entry.g,
                tm_n,
                accuracy: entry.quant_test_acc,
                admitted: violations.is_empty(),
                violations,
                report,
            });
        }
    }
    let best = candidates
        .iter()
        .filter(|c| c.admitted)
        .max_by(|a, b| {
            a.accuracy
                .partial_cmp(&b.accuracy)
                .unwrap_or(std::cmp::Ordering::Equal)
                // among equal accuracy prefer lower energy
                .then(
                    b.report
                        .energy_pj
                        .partial_cmp(&a.report.energy_pj)
                        .unwrap_or(std::cmp::Ordering::Equal),
                )
        })
        .cloned();
    Ok(SearchOutcome { candidates, best })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sweep() -> Vec<SweepEntry> {
        vec![
            SweepEntry { g: 7, num_params: 341, val_acc: 0.80, quant_test_acc: 0.80, weights: "a".into() },
            SweepEntry { g: 15, num_params: 589, val_acc: 0.83, quant_test_acc: 0.83, weights: "b".into() },
            SweepEntry { g: 30, num_params: 1054, val_acc: 0.85, quant_test_acc: 0.85, weights: "c".into() },
            SweepEntry { g: 60, num_params: 1984, val_acc: 0.86, quant_test_acc: 0.86, weights: "d".into() },
        ]
    }

    #[test]
    fn unconstrained_search_picks_highest_accuracy() {
        let out = search(
            &[17, 1, 14],
            &sweep(),
            &[3],
            &HwConstraints::default(),
            &Tech::default(),
        )
        .unwrap();
        assert_eq!(out.candidates.len(), 4);
        assert_eq!(out.best.as_ref().unwrap().g, 60);
    }

    #[test]
    fn tight_budget_forces_smaller_g() {
        // find a budget that admits G=7 but not G=60
        let t = Tech::default();
        let r7 = estimate_kan(&KanArch::new(vec![17, 1, 14], 7), &t).unwrap();
        let r60 = estimate_kan(&KanArch::new(vec![17, 1, 14], 60), &t).unwrap();
        assert!(r60.area_mm2 > r7.area_mm2);
        let budget = HwConstraints {
            max_area_mm2: Some((r7.area_mm2 + r60.area_mm2) / 2.0),
            max_energy_pj: None,
            max_latency_ns: None,
        };
        let out = search(&[17, 1, 14], &sweep(), &[3], &budget, &t).unwrap();
        let best = out.best.unwrap();
        assert!(best.g < 60, "budget should exclude G=60, got G={}", best.g);
        // and the excluded candidate carries its violation reason
        assert!(out
            .candidates
            .iter()
            .any(|c| c.g == 60 && !c.admitted && !c.violations.is_empty()));
    }

    #[test]
    fn impossible_budget_yields_no_winner() {
        let budget = HwConstraints {
            max_area_mm2: Some(1e-9),
            ..Default::default()
        };
        let out = search(&[17, 1, 14], &sweep(), &[2, 3, 4], &budget, &Tech::default()).unwrap();
        assert!(out.best.is_none());
        assert_eq!(out.candidates.len(), 12);
    }
}
