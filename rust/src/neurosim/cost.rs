//! KAN-NeuroSim cost engine: full-accelerator area / energy / latency
//! estimation for KAN and conventional-MLP accelerators at 22 nm
//! (the NeuroSim [17] role in the paper's Fig 9 loop; DESIGN.md §4).
//!
//! Everything is counted from structure:
//!
//! * KAN accelerator = per layer: ASP-KAN-HAQ B(X) path (one per input
//!   channel), one shared TM-DV-IG pulse engine + per-WL buffers, the ci'
//!   crossbar (din·(G+K) × dout cells), column ADCs, digital accumulate,
//!   plus the w_b·ReLU residual crossbar (din × dout).
//! * MLP accelerator = conventional RRAM-ACIM: 8-bit binary-serial inputs
//!   (8 cycles), din × dout crossbars tiled to the array size, column ADCs
//!   per cycle — no LUT path, but 680x the cells and 8x the cycles.


use crate::circuits::bx_path::{cost_bx_path, BxPathDesign};
use crate::circuits::components::ColumnAdc;
use crate::circuits::inputgen::{InputGenerator, TmDvIg};
use crate::circuits::tech::{Cost, Tech};
use crate::error::Result;

/// Architecture summary fed to the estimator.
#[derive(Debug, Clone)]
pub struct KanArch {
    pub dims: Vec<usize>,
    pub g: u32,
    pub k: u32,
    pub n_bits: u32,
    /// TM-DV-IG voltage bits (N); latency/accuracy trade (TD-P vs TD-A).
    pub tm_n: u32,
    /// Physical array rows per tile.
    pub array_rows: usize,
}

impl KanArch {
    pub fn new(dims: Vec<usize>, g: u32) -> Self {
        Self { dims, g, k: 3, n_bits: 8, tm_n: 3, array_rows: 256 }
    }

    pub fn num_edges(&self) -> usize {
        self.dims.windows(2).map(|w| w[0] * w[1]).sum()
    }

    /// Paper's parameter count: (G + K + 1) per edge.
    pub fn num_params(&self) -> usize {
        self.num_edges() * (self.g + self.k + 1) as usize
    }
}

#[derive(Debug, Clone)]
pub struct MlpArch {
    pub dims: Vec<usize>,
    pub weight_bits: u32,
    pub array_rows: usize,
}

impl MlpArch {
    pub fn new(dims: Vec<usize>) -> Self {
        Self { dims, weight_bits: 8, array_rows: 256 }
    }

    pub fn num_params(&self) -> usize {
        self.dims.windows(2).map(|w| (w[0] + 1) * w[1]).sum()
    }
}

/// Accelerator-level cost report (one inference).
#[derive(Debug, Clone)]
pub struct AccelReport {
    pub name: String,
    pub area_mm2: f64,
    pub energy_pj: f64,
    pub latency_ns: f64,
    pub num_params: usize,
    /// itemized per-layer costs (area µm², energy fJ, latency ns)
    pub per_layer: Vec<Cost>,
}

/// Per-active-cell MAC energy (fJ) — charge deposited on the BL.
const CELL_MAC_FJ: f64 = 2.0;
/// Per-WL driver area (buffer + level shifter + routing pitch), µm².
const WL_DRIVER_AREA_UM2: f64 = 12.0;
/// Fixed per-layer digital overhead: accumulate, requantize, control.
const DIGITAL_LAT_NS: f64 = 6.0;
const DIGITAL_FJ: f64 = 5_000.0;
const DIGITAL_AREA_UM2: f64 = 200.0;
/// Global overhead outside the layer pipeline (I/O, clocking, scheduling).
const GLOBAL_LAT_NS: f64 = 20.0;
const GLOBAL_AREA_UM2: f64 = 500.0;
const GLOBAL_FJ: f64 = 30_000.0;
/// ADC budget of the conventional (MLP) accelerator — a traditional design
/// shares a fixed converter pool across all columns, serializing rounds.
const MLP_ADC_BUDGET: usize = 64;
/// Per-column sense amplifier of the conventional design (area µm² / fJ):
/// every column pair carries an SA even though precision conversion is
/// serialized through the shared ADC pool.
const SA_AREA_UM2: f64 = 60.0;
const SA_ENERGY_FJ: f64 = 10.0;

/// Estimate a KAN accelerator built with all three of the paper's
/// techniques (ASP-KAN-HAQ B(X) path, TM-DV-IG inputs, KAN-SAM mapping —
/// the last is free in cost terms).
pub fn estimate_kan(arch: &KanArch, t: &Tech) -> Result<AccelReport> {
    let tm = TmDvIg { n_voltage_bits: arch.tm_n };
    let lut_bits = arch.n_bits; // B(X) drive width == LUT word width
    let mut per_layer = Vec::new();
    let mut total = Cost::default();
    for w in arch.dims.windows(2) {
        let (din, dout) = (w[0], w[1]);
        let nb = (arch.g + arch.k) as usize;
        let rows = din * nb + din; // spline rows + residual rows

        // B(X) path: ONE shared ASP unit per layer, time-multiplexed across
        // the din input channels (Fig 6: "multiple Xi share a single
        // SH-LUT"); energy scales with din lookups.
        let bx = cost_bx_path(BxPathDesign::AspFull, arch.g, arch.k, arch.n_bits, t)?;
        let bx_layer = Cost::new(
            bx.total.area_um2,
            bx.total.energy_fj * din as f64,
            bx.total.latency_ns,
        );

        // input generation: shared DAC + delay chain + PM-TCM per layer,
        // a driver per WL; per inference din*(K+1) spline WLs + din
        // residual WLs fire for the full drive window.
        let ig = tm.report(lut_bits, t);
        let active_wl = din * (arch.k as usize + 1) + din;
        let ig_cost = Cost::new(
            ig.area_um2 - t.buffer_area_um2 + rows as f64 * WL_DRIVER_AREA_UM2,
            ig.power_uw * ig.latency_ns
                + active_wl as f64 * t.buffer_power_uw * ig.latency_ns,
            ig.latency_ns,
        );

        // crossbar: differential pairs -> 2x cells
        let cells = 2 * rows * dout;
        let xbar = Cost::new(
            cells as f64 * t.rram_cell_area_um2 * t.routing_factor,
            (active_wl * dout) as f64 * CELL_MAC_FJ,
            1.0,
        );

        // column ADCs
        let adc = ColumnAdc.cost(t, dout);

        let digital = Cost::new(DIGITAL_AREA_UM2, DIGITAL_FJ, DIGITAL_LAT_NS);
        let layer = bx_layer
            .series(ig_cost)
            .series(xbar)
            .series(adc)
            .series(digital);
        per_layer.push(layer);
        total = total.series(layer);
    }
    total = total.series(Cost::new(GLOBAL_AREA_UM2, GLOBAL_FJ, GLOBAL_LAT_NS));
    Ok(AccelReport {
        name: format!("kan-{:?}-g{}", arch.dims, arch.g),
        area_mm2: total.area_um2 / 1e6,
        energy_pj: total.energy_fj / 1e3,
        latency_ns: total.latency_ns,
        num_params: arch.num_params(),
        per_layer,
    })
}

/// Estimate the conventional-MLP RRAM-ACIM accelerator (Fig 13 baseline):
/// binary-serial 8-bit inputs, tiled crossbars, a fixed shared ADC pool
/// converting every column every cycle.
pub fn estimate_mlp(arch: &MlpArch, t: &Tech) -> Result<AccelReport> {
    let cycles = arch.weight_bits as usize; // bit-serial input
    let mut per_layer = Vec::new();
    let mut total = Cost::default();
    for w in arch.dims.windows(2) {
        let (din, dout) = (w[0], w[1]);
        let row_tiles = din.div_ceil(arch.array_rows);

        // WL drivers: binary buffer per row + serial control
        let drivers = Cost::new(
            din as f64 * t.buffer_area_um2 + t.pm_tcm_area_um2,
            din as f64 * t.buffer_power_uw * t.unit_pulse_ns * cycles as f64 * 0.5,
            cycles as f64 * t.unit_pulse_ns,
        );

        // crossbar (differential)
        let cells = 2 * din * dout;
        let xbar = Cost::new(
            cells as f64 * t.rram_cell_area_um2 * t.routing_factor,
            (din * dout * cycles) as f64 * CELL_MAC_FJ * 0.5, // avg half bits set
            1.0,
        );

        // fixed ADC pool: every (column, row-tile) partial sum is converted
        // every input cycle, serialized over the pool
        let conversions = dout * row_tiles;
        let converters = MLP_ADC_BUDGET.min(conversions);
        let rounds = conversions.div_ceil(converters.max(1));
        let adc = Cost::new(
            converters as f64 * t.adc_area_um2 + conversions as f64 * SA_AREA_UM2,
            (conversions * cycles) as f64 * (t.adc_energy_fj + SA_ENERGY_FJ),
            (rounds * cycles) as f64 * t.adc_time_ns,
        );

        // shift-add accumulators across bit-serial cycles
        let digital = Cost::new(
            DIGITAL_AREA_UM2 + dout as f64 * 8.0 * t.gate_area_um2,
            DIGITAL_FJ * cycles as f64 / 4.0,
            DIGITAL_LAT_NS,
        );

        let layer = drivers.series(xbar).series(adc).series(digital);
        per_layer.push(layer);
        total = total.series(layer);
    }
    total = total.series(Cost::new(GLOBAL_AREA_UM2, GLOBAL_FJ, GLOBAL_LAT_NS));
    Ok(AccelReport {
        name: format!("mlp-{:?}", arch.dims),
        area_mm2: total.area_um2 / 1e6,
        energy_pj: total.energy_fj / 1e3,
        latency_ns: total.latency_ns,
        num_params: arch.num_params(),
        per_layer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kan1() -> KanArch {
        KanArch::new(vec![17, 1, 14], 5)
    }

    fn kan2() -> KanArch {
        KanArch::new(vec![17, 2, 14], 32)
    }

    fn mlp() -> MlpArch {
        MlpArch::new(vec![17, 420, 420, 14])
    }

    #[test]
    fn param_counts_match_paper() {
        assert_eq!(kan1().num_params(), 279); // paper: 279
        assert_eq!(kan2().num_params(), 2232); // paper: 2232
        assert_eq!(mlp().num_params(), 190_274); // paper: 190,214 (+0.03%)
    }

    #[test]
    fn fig13_ratios_in_band() {
        // paper: KAN1 vs MLP: 41.78x area, 77.97x energy, 29.56x latency;
        //        KAN2 vs MLP:  9.28x area, 51.04x energy, 23.59x latency.
        let t = Tech::default();
        let m = estimate_mlp(&mlp(), &t).unwrap();
        let k1 = estimate_kan(&kan1(), &t).unwrap();
        let k2 = estimate_kan(&kan2(), &t).unwrap();

        let a1 = m.area_mm2 / k1.area_mm2;
        let e1 = m.energy_pj / k1.energy_pj;
        let l1 = m.latency_ns / k1.latency_ns;
        assert!((20.0..80.0).contains(&a1), "KAN1 area reduction {a1:.1} (paper 41.78)");
        assert!((35.0..160.0).contains(&e1), "KAN1 energy reduction {e1:.1} (paper 77.97)");
        assert!((9.0..60.0).contains(&l1), "KAN1 latency reduction {l1:.1} (paper 29.56)");

        let a2 = m.area_mm2 / k2.area_mm2;
        let e2 = m.energy_pj / k2.energy_pj;
        let l2 = m.latency_ns / k2.latency_ns;
        assert!((4.0..25.0).contains(&a2), "KAN2 area reduction {a2:.1} (paper 9.28)");
        assert!((20.0..110.0).contains(&e2), "KAN2 energy reduction {e2:.1} (paper 51.04)");
        assert!((9.0..50.0).contains(&l2), "KAN2 latency reduction {l2:.1} (paper 23.59)");

        // orderings that must hold exactly
        assert!(k1.area_mm2 < k2.area_mm2, "KAN1 smaller than KAN2");
        assert!(k1.energy_pj < k2.energy_pj);
        assert!(k2.area_mm2 < m.area_mm2);
    }

    #[test]
    fn absolute_magnitudes_plausible() {
        // sanity: same order of magnitude as the paper's absolute numbers
        let t = Tech::default();
        let m = estimate_mlp(&mlp(), &t).unwrap();
        assert!(
            (0.05..5.0).contains(&m.area_mm2),
            "MLP area {} mm2 (paper 0.585)",
            m.area_mm2
        );
        assert!(
            (2_000.0..200_000.0).contains(&m.energy_pj),
            "MLP energy {} pJ (paper 20049)",
            m.energy_pj
        );
        let k1 = estimate_kan(&kan1(), &t).unwrap();
        assert!(
            (0.002..0.2).contains(&k1.area_mm2),
            "KAN1 area {} mm2 (paper 0.014)",
            k1.area_mm2
        );
    }

    #[test]
    fn td_p_mode_is_faster() {
        let t = Tech::default();
        let mut fast = kan2();
        fast.tm_n = 4; // TD-P
        let mut slow = kan2();
        slow.tm_n = 2; // TD-A
        let f = estimate_kan(&fast, &t).unwrap();
        let s = estimate_kan(&slow, &t).unwrap();
        assert!(f.latency_ns < s.latency_ns);
    }

    #[test]
    fn kan_cost_monotone_in_g() {
        let t = Tech::default();
        let mut last_area = 0.0;
        for g in [4u32, 8, 16, 32, 64] {
            let r = estimate_kan(&KanArch::new(vec![17, 1, 14], g), &t).unwrap();
            assert!(r.area_mm2 > last_area, "G={g}");
            last_area = r.area_mm2;
        }
    }
}
