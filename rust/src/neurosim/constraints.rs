//! Hardware constraints for the Fig 9 search loop.


use super::cost::AccelReport;

/// User-specified hardware budget (any field `None` = unconstrained).
#[derive(Debug, Clone, Copy, Default)]
pub struct HwConstraints {
    pub max_area_mm2: Option<f64>,
    pub max_energy_pj: Option<f64>,
    pub max_latency_ns: Option<f64>,
}

impl HwConstraints {
    /// The paper's "minimal" budget (admits only the smallest designs —
    /// the KAN1 class). Values are in this crate's cost-model scale, which
    /// sits ~4x below the paper's absolute numbers (EXPERIMENTS.md §Fig13).
    pub fn minimal() -> Self {
        Self {
            max_area_mm2: Some(0.005),
            max_energy_pj: Some(50.0),
            max_latency_ns: Some(200.0),
        }
    }

    /// The paper's "moderate" budget (admits KAN2-class designs).
    pub fn moderate() -> Self {
        Self {
            max_area_mm2: Some(0.012),
            max_energy_pj: Some(55.0),
            max_latency_ns: Some(250.0),
        }
    }

    /// Does a cost report fit the budget?
    pub fn admits(&self, r: &AccelReport) -> bool {
        self.max_area_mm2.map_or(true, |m| r.area_mm2 <= m)
            && self.max_energy_pj.map_or(true, |m| r.energy_pj <= m)
            && self.max_latency_ns.map_or(true, |m| r.latency_ns <= m)
    }

    /// Which constraint is violated (for the Fig 9 refinement loop).
    pub fn violations(&self, r: &AccelReport) -> Vec<String> {
        let mut v = Vec::new();
        if let Some(m) = self.max_area_mm2 {
            if r.area_mm2 > m {
                v.push(format!("area {:.4} mm2 > {:.4}", r.area_mm2, m));
            }
        }
        if let Some(m) = self.max_energy_pj {
            if r.energy_pj > m {
                v.push(format!("energy {:.1} pJ > {:.1}", r.energy_pj, m));
            }
        }
        if let Some(m) = self.max_latency_ns {
            if r.latency_ns > m {
                v.push(format!("latency {:.0} ns > {:.0}", r.latency_ns, m));
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::Tech;
    use crate::neurosim::cost::{estimate_kan, KanArch};

    #[test]
    fn unconstrained_admits_everything() {
        let c = HwConstraints::default();
        let r = estimate_kan(&KanArch::new(vec![17, 1, 14], 64), &Tech::default()).unwrap();
        assert!(c.admits(&r));
        assert!(c.violations(&r).is_empty());
    }

    #[test]
    fn tight_budget_rejects_with_reasons() {
        let c = HwConstraints {
            max_area_mm2: Some(1e-6),
            max_energy_pj: Some(1e-3),
            max_latency_ns: None,
        };
        let r = estimate_kan(&KanArch::new(vec![17, 1, 14], 8), &Tech::default()).unwrap();
        assert!(!c.admits(&r));
        assert_eq!(c.violations(&r).len(), 2);
    }
}
