//! KAN-NeuroSim: the hyperparameter / hardware co-optimization framework
//! (paper §3.4, Fig 9).
//!
//! * [`cost`] — the NeuroSim-role estimator: accelerator-level area /
//!   energy / latency for KAN and conventional-MLP designs.
//! * [`constraints`] — user hardware budgets (energy, area, latency).
//! * [`search`] — step 1 of Fig 9: find the admissible (G, TM-DV mode)
//!   design points and pick the best against the training sweep manifest
//!   produced by the python build path (grid extension = step 2 lives in
//!   `python/compile/train.py`, which this search consumes the output of).

pub mod constraints;
pub mod cost;
pub mod search;

pub use constraints::HwConstraints;
pub use cost::{estimate_kan, estimate_mlp, AccelReport, KanArch, MlpArch};
pub use search::{search, CandidateResult, SearchOutcome};
