//! # kan-edge
//!
//! Production-quality reproduction of *"Hardware Acceleration of
//! Kolmogorov–Arnold Network (KAN) for Lightweight Edge Inference"*
//! (Huang et al., 2024) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate contains:
//!
//! * [`quant`] — ASP-KAN-HAQ: Alignment-Symmetry + PowerGap hardware-aware
//!   quantization with the Sharable-Hemi LUT, plus the conventional
//!   (PACT-style) baseline it is compared against (paper §3.1, Fig 10).
//! * [`circuits`] — analytic 22 nm component models (LUTs, decoders,
//!   TG-MUXes, DACs, delay chains) and the three word-line input
//!   generators: pure-voltage, pure-PWM and the paper's N:1 Time-Modulation
//!   Dynamic-Voltage generator (§3.2, Fig 11).
//! * [`acim`] — a behavioural RRAM analog compute-in-memory simulator:
//!   conductance programming, bit-line IR-drop (resistive-ladder model),
//!   device variation, ADC partial-sum quantization (§2.2, §3.3).
//! * [`mapping`] — KAN-SAM sparsity-aware weight mapping (§3.3, Fig 12).
//! * [`neurosim`] — the KAN-NeuroSim hyperparameter/hardware co-search
//!   framework: full-accelerator area/energy/latency estimation and the
//!   constraint-driven G search (§3.4, Fig 9/13).
//! * [`kan`] — B-spline math, float and quantized-integer KAN inference,
//!   checkpoint loading for the artifacts produced by `python/compile/`.
//! * [`baseline`] — the traditional-MLP accelerator baseline of Fig 13.
//! * [`runtime`] — PJRT execution of the AOT-lowered HLO artifacts
//!   (behind the off-by-default `pjrt` cargo feature; a stub with clear
//!   errors compiles in otherwise).
//! * [`coordinator`] — the edge-inference serving runtime: dynamic
//!   batching, routing, backend pool, per-model metrics with an exact
//!   aggregate rollup, and the wire surface — the typed
//!   [`coordinator::protocol`] (framed, pipelined v2 with control-plane
//!   verbs) over the [`coordinator::tcp`] transport, which auto-detects
//!   legacy v1 JSON-lines clients per connection. `docs/PROTOCOL.md`
//!   specifies both formats.
//! * [`client`] — the typed Rust client ([`client::KanClient`]):
//!   connect/negotiate, `infer`, batch submit, pipelined
//!   `submit`/`poll`, and registry/metrics/health queries.
//! * [`obs`] — observability: sampled end-to-end request tracing with
//!   per-stage timestamps, the SAM mapping-drift statistic, a
//!   Prometheus text-format exposition of every counter, and the
//!   structured leveled JSON logger. `docs/OBSERVABILITY.md` documents
//!   the span stages and the overhead contract.
//! * [`registry`] — model registry & multi-model serving: the
//!   schema-tagged manifest (v1 = flat aot.py output, v2 = per-model
//!   version/digest/quant/hardware-cost metadata), a content-addressed
//!   artifact store with integrity verification, and the hot-reloadable
//!   [`registry::ModelRegistry`] serving many `name@version` variants
//!   behind one TCP endpoint (requests carry an optional `"model"`
//!   field; see [`coordinator::tcp`] for the wire protocol).
//! * [`rollout`] — staged canary deployments: a deterministic traffic
//!   splitter ramps the manifest-current version against the retained
//!   previous version while SLO gates (argmax-flip rate, logit-MAE p99,
//!   latency regression) auto-promote a clean canary or instantly roll
//!   back a breaching one. `docs/ROLLOUT.md` covers the state machine,
//!   gates and `rollout_*` control verbs.
//!
//! Python (JAX + Pallas) appears only in the build path (`make artifacts`);
//! this crate is self-contained at run time.

// config structs are routinely built as default-then-override (tests,
// examples, callers); the style lint fights that idiom
#![allow(clippy::field_reassign_with_default)]
// a `pub` item that is not actually reachable from outside the crate is
// a doc lie — surface it (kan-edge lint's drift family covers docs; the
// compiler covers visibility)
#![warn(unreachable_pub)]

pub mod acim;
pub mod analysis;
pub mod baseline;
pub mod circuits;
pub mod client;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod kan;
pub mod mapping;
pub mod neurosim;
pub mod obs;
pub mod quant;
pub mod registry;
pub mod rollout;
pub mod runtime;
pub mod util;

pub use error::{Error, Result};
