//! Consistent-hash ring with virtual nodes.
//!
//! Each cluster node contributes `vnodes` points on a 64-bit ring,
//! derived from its name with the crate's FNV-1a digest and the
//! split-mix `mix` (see [`crate::util::rng`]) — the same primitives the
//! registry and the seeded backends use, so the ring costs no new
//! hashing code. A key routes to the first point at or after its own
//! hash (wrapping), and its replica set is the next `rf` *distinct*
//! nodes clockwise from there.
//!
//! Virtual nodes bound key movement under membership change: adding or
//! removing one node of `n` moves only the keys whose arcs it owned,
//! about `1/n` of the space, instead of reshuffling everything the way
//! `hash % n` would. `rust/tests/cluster.rs` asserts that bound.

use crate::registry::digest::fnv64;
use crate::util::rng::mix;

/// Salt mixed into key hashes so a key and an identically-named node
/// never collide onto the same point by construction.
const KEY_SALT: u64 = 0x6b65795f73616c74; // "key_salt"

/// Immutable ring over a static node list (index = position in the
/// configured `cluster.nodes` order).
#[derive(Debug, Clone)]
pub struct HashRing {
    /// Sorted `(point, node index)` pairs.
    points: Vec<(u64, usize)>,
    nodes: usize,
}

impl HashRing {
    /// Build a ring with `vnodes` points per node. Node identity is the
    /// *name* (its configured address string), so the ring layout is
    /// identical on every router that shares the config.
    pub fn new(node_names: &[String], vnodes: usize) -> Self {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(node_names.len() * vnodes);
        for (idx, name) in node_names.iter().enumerate() {
            let base = fnv64(name.as_bytes());
            for v in 0..vnodes {
                points.push((mix(base, v as u64), idx));
            }
        }
        // ties (64-bit collisions) resolve by node index, deterministically
        points.sort_unstable();
        Self { points, nodes: node_names.len() }
    }

    /// Number of nodes the ring was built over.
    pub fn node_count(&self) -> usize {
        self.nodes
    }

    fn key_point(key: &str) -> u64 {
        mix(fnv64(key.as_bytes()), KEY_SALT)
    }

    /// The first `rf` distinct nodes clockwise from `key`'s point, in
    /// preference order (primary first). Fewer than `rf` when the ring
    /// has fewer nodes; empty only for an empty ring.
    pub fn replicas(&self, key: &str, rf: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(rf.min(self.nodes));
        if self.points.is_empty() || rf == 0 {
            return out;
        }
        let h = Self::key_point(key);
        let start = self.points.partition_point(|&(p, _)| p < h);
        for i in 0..self.points.len() {
            let (_, node) = self.points[(start + i) % self.points.len()];
            if !out.contains(&node) {
                out.push(node);
                if out.len() == rf.min(self.nodes) {
                    break;
                }
            }
        }
        out
    }

    /// The key's primary owner.
    pub fn primary(&self, key: &str) -> Option<usize> {
        self.replicas(key, 1).first().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("node-{i}:77{i:02}")).collect()
    }

    #[test]
    fn replicas_are_distinct_and_stable() {
        let ring = HashRing::new(&names(5), 64);
        for k in 0..200 {
            let key = format!("model-{k}@1");
            let r = ring.replicas(&key, 3);
            assert_eq!(r.len(), 3);
            let mut sorted = r.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 3, "duplicate replica for {key}: {r:?}");
            // deterministic across ring rebuilds from the same config
            assert_eq!(HashRing::new(&names(5), 64).replicas(&key, 3), r);
        }
    }

    #[test]
    fn rf_larger_than_cluster_returns_every_node() {
        let ring = HashRing::new(&names(3), 16);
        let r = ring.replicas("m@1", 5);
        let mut sorted = r.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2]);
        assert!(HashRing::new(&[], 16).replicas("m@1", 2).is_empty());
    }

    #[test]
    fn join_moves_a_bounded_fraction_of_keys() {
        let before = HashRing::new(&names(4), 64);
        let mut grown = names(4);
        grown.push("node-4:7704".into());
        let after = HashRing::new(&grown, 64);
        let total = 2000;
        let mut moved = 0;
        for k in 0..total {
            let key = format!("model-{k}@1");
            let (b, a) = (before.primary(&key).unwrap(), after.primary(&key).unwrap());
            if b != a {
                // keys only ever move *to* the joining node, never
                // between the survivors
                assert_eq!(a, 4, "key {key} moved {b} -> {a}");
                moved += 1;
            }
        }
        // ideal is 1/5 of the keys; allow generous slack for vnode variance
        assert!(
            moved > 0 && (moved as f64) < 0.45 * total as f64,
            "join moved {moved}/{total} keys"
        );
    }

    #[test]
    fn keys_balance_roughly_across_nodes() {
        let ring = HashRing::new(&names(4), 64);
        let mut counts = [0usize; 4];
        for k in 0..4000 {
            counts[ring.primary(&format!("key-{k}")).unwrap()] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                c > 400 && c < 2200,
                "node {i} owns {c}/4000 keys: {counts:?}"
            );
        }
    }
}
