//! Hedged-retry policy: when the primary replica has not answered
//! within a quantile of recently observed latencies, reissue the
//! request to the next replica and take whichever answer lands first.
//!
//! Safe because the data path is idempotent by construction: the wire
//! layer resolves the noise seed *before* routing, so both replicas
//! compute the same bit-identical output for the same `(row, seed)` —
//! a hedge can change who answers, never what the answer is.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::sync::LockExt;
use crate::coordinator::metrics::percentile;

/// Samples needed before the quantile is trusted; below this the delay
/// is the configured maximum (conservative: cold routers barely hedge).
const WARM_SAMPLES: usize = 8;

/// Bounded ring of recent request latencies (millis) plus the knobs
/// that turn its quantile into a hedge delay.
pub struct HedgePolicy {
    quantile: f64,
    min_ms: u64,
    max_ms: u64,
    window: Mutex<Window>,
}

struct Window {
    samples: Vec<u64>,
    next: usize,
}

impl HedgePolicy {
    /// `quantile` in `(0, 1]`; the derived delay is clamped to
    /// `[min_ms, max_ms]`.
    pub fn new(quantile: f64, min_ms: u64, max_ms: u64) -> Self {
        Self {
            quantile: quantile.clamp(0.01, 1.0),
            min_ms: min_ms.min(max_ms),
            max_ms: max_ms.max(min_ms).max(1),
            window: Mutex::new(Window { samples: Vec::with_capacity(512), next: 0 }),
        }
    }

    /// Record one successful first-answer latency.
    pub fn record(&self, latency: Duration) {
        let ms = latency.as_millis().min(u128::from(u64::MAX)) as u64;
        let mut w = self.window.lock_recover();
        if w.samples.len() < 512 {
            w.samples.push(ms);
        } else {
            let at = w.next;
            w.samples[at] = ms;
            w.next = (at + 1) % 512;
        }
    }

    /// Current hedge delay: the configured quantile of the window,
    /// clamped, or `max_ms` while the window is cold.
    pub fn delay(&self) -> Duration {
        let w = self.window.lock_recover();
        let ms = if w.samples.len() < WARM_SAMPLES {
            self.max_ms
        } else {
            let mut sorted = w.samples.clone();
            sorted.sort_unstable();
            percentile(&sorted, self.quantile).clamp(self.min_ms, self.max_ms)
        };
        Duration::from_millis(ms)
    }

    /// Observed sample count (for the metrics rollup).
    pub fn samples(&self) -> usize {
        self.window.lock_recover().samples.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_window_uses_the_maximum() {
        let h = HedgePolicy::new(0.9, 1, 40);
        assert_eq!(h.delay(), Duration::from_millis(40));
        for _ in 0..WARM_SAMPLES - 1 {
            h.record(Duration::from_millis(2));
        }
        assert_eq!(h.delay(), Duration::from_millis(40), "still one sample short");
    }

    #[test]
    fn warm_window_tracks_the_quantile_clamped() {
        let h = HedgePolicy::new(0.9, 5, 100);
        for ms in [1u64, 1, 1, 2, 2, 2, 3, 3, 3, 50] {
            h.record(Duration::from_millis(ms));
        }
        // p90 of the window is 3ms -> clamped up to min_ms=5
        assert_eq!(h.delay(), Duration::from_millis(5));
        for _ in 0..40 {
            h.record(Duration::from_millis(400));
        }
        // dominated by 400ms samples -> clamped down to max_ms=100
        assert_eq!(h.delay(), Duration::from_millis(100));
    }

    #[test]
    fn window_is_bounded() {
        let h = HedgePolicy::new(0.5, 1, 1000);
        for _ in 0..2000 {
            h.record(Duration::from_millis(7));
        }
        assert_eq!(h.samples(), 512);
        assert_eq!(h.delay(), Duration::from_millis(7));
    }
}
