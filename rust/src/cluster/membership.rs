//! Cluster membership as seen by one router: per-node liveness driven
//! by the heartbeat loop (and by transport failures observed on the
//! data path, which count the same — a request that cannot connect is
//! better evidence than a heartbeat that has not fired yet).
//!
//! States: `Up` (routable), `Down` (after `fail_after` consecutive
//! failures; first success recovers it), `Draining` (operator-set: no
//! new work is routed there, but the node keeps being heartbeated and
//! can still serve as a replication source).

use std::sync::Mutex;

use crate::util::sync::LockExt;
use crate::util::json::{obj, Value};

/// Routing view of one node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    Up,
    Down,
    Draining,
}

impl NodeState {
    pub fn as_str(&self) -> &'static str {
        match self {
            NodeState::Up => "up",
            NodeState::Down => "down",
            NodeState::Draining => "draining",
        }
    }
}

#[derive(Debug, Clone)]
struct NodeView {
    state: NodeState,
    consecutive_failures: u32,
    /// Stable identity the node reported in `health` (None until the
    /// first successful probe, or for pre-identity servers).
    node_id: Option<String>,
    models_live: usize,
    uptime_s: Option<u64>,
}

/// Membership table over the static configured node list. Index `i`
/// here is index `i` in `cluster.nodes` and in the hash ring.
pub struct Membership {
    addrs: Vec<String>,
    fail_after: u32,
    views: Vec<Mutex<NodeView>>,
}

impl Membership {
    /// All nodes start `Up` (optimistic): traffic can route before the
    /// first heartbeat, and a dead node is demoted after `fail_after`
    /// observed failures from either the heartbeat or the data path.
    pub fn new(addrs: Vec<String>, fail_after: u32) -> Self {
        let views = addrs
            .iter()
            .map(|_| {
                Mutex::new(NodeView {
                    state: NodeState::Up,
                    consecutive_failures: 0,
                    node_id: None,
                    models_live: 0,
                    uptime_s: None,
                })
            })
            .collect();
        Self { addrs, fail_after: fail_after.max(1), views }
    }

    pub fn len(&self) -> usize {
        self.addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.addrs.is_empty()
    }

    pub fn addr(&self, idx: usize) -> &str {
        &self.addrs[idx]
    }

    pub fn state(&self, idx: usize) -> NodeState {
        self.views[idx].lock_recover().state
    }

    /// Identity label for metrics/rollups: the reported `node_id` when
    /// known, else the configured address.
    pub fn label(&self, idx: usize) -> String {
        let v = self.views[idx].lock_recover();
        v.node_id.clone().unwrap_or_else(|| self.addrs[idx].clone())
    }

    /// May new requests be routed to this node?
    pub fn is_routable(&self, idx: usize) -> bool {
        self.state(idx) == NodeState::Up
    }

    pub fn up_count(&self) -> usize {
        (0..self.len()).filter(|&i| self.is_routable(i)).count()
    }

    /// A successful probe or data-path call: resets the failure streak
    /// and recovers a `Down` node (a `Draining` node stays draining —
    /// that flag is operator intent, not an observation).
    pub fn record_ok(
        &self,
        idx: usize,
        node_id: Option<String>,
        models_live: usize,
        uptime_s: Option<u64>,
    ) {
        let mut v = self.views[idx].lock_recover();
        v.consecutive_failures = 0;
        if let Some(id) = node_id {
            v.node_id = Some(id);
        }
        v.models_live = models_live;
        if uptime_s.is_some() {
            v.uptime_s = uptime_s;
        }
        if v.state == NodeState::Down {
            v.state = NodeState::Up;
        }
    }

    /// A failed probe or data-path transport error. Returns `true` when
    /// this failure transitioned the node to `Down`.
    pub fn record_failure(&self, idx: usize) -> bool {
        let mut v = self.views[idx].lock_recover();
        v.consecutive_failures = v.consecutive_failures.saturating_add(1);
        if v.state == NodeState::Up && v.consecutive_failures >= self.fail_after {
            v.state = NodeState::Down;
            return true;
        }
        false
    }

    /// Operator drain toggle. Un-draining returns the node to `Up`; the
    /// next failures can still demote it normally.
    pub fn set_draining(&self, idx: usize, draining: bool) {
        let mut v = self.views[idx].lock_recover();
        v.state = if draining { NodeState::Draining } else { NodeState::Up };
        if !draining {
            v.consecutive_failures = 0;
        }
    }

    /// Flat per-node status objects for the metrics rollup, keyed by
    /// the node label (reported id, else address).
    pub fn summaries(&self) -> Vec<(String, Value)> {
        (0..self.len())
            .map(|i| {
                let v = self.views[i].lock_recover();
                let label =
                    v.node_id.clone().unwrap_or_else(|| self.addrs[i].clone());
                let body = obj(vec![
                    ("addr", Value::Str(self.addrs[i].clone())),
                    ("state", Value::Str(v.state.as_str().to_string())),
                    ("up", Value::Int((v.state == NodeState::Up) as i64)),
                    ("models_live", Value::Int(v.models_live as i64)),
                    (
                        "uptime_s",
                        v.uptime_s.map(|u| Value::Int(u as i64)).unwrap_or(Value::Null),
                    ),
                ]);
                (label, body)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two() -> Membership {
        Membership::new(vec!["a:1".into(), "b:2".into()], 2)
    }

    #[test]
    fn fails_down_after_threshold_and_recovers() {
        let m = two();
        assert!(m.is_routable(0));
        assert!(!m.record_failure(0));
        assert!(m.is_routable(0), "one failure below fail_after=2 must not demote");
        assert!(m.record_failure(0));
        assert_eq!(m.state(0), NodeState::Down);
        assert_eq!(m.up_count(), 1);
        // repeated failures do not re-report the transition
        assert!(!m.record_failure(0));
        m.record_ok(0, Some("n0".into()), 3, Some(12));
        assert_eq!(m.state(0), NodeState::Up);
        assert_eq!(m.label(0), "n0");
    }

    #[test]
    fn draining_is_not_routable_but_not_down() {
        let m = two();
        m.set_draining(1, true);
        assert!(!m.is_routable(1));
        assert_eq!(m.state(1), NodeState::Draining);
        // observations do not overrule operator intent
        m.record_ok(1, None, 0, None);
        assert_eq!(m.state(1), NodeState::Draining);
        m.set_draining(1, false);
        assert!(m.is_routable(1));
    }

    #[test]
    fn summaries_key_by_id_when_known() {
        let m = two();
        m.record_ok(0, Some("alpha".into()), 2, Some(5));
        let s = m.summaries();
        assert_eq!(s[0].0, "alpha");
        assert_eq!(s[1].0, "b:2");
        assert_eq!(s[0].1.get("up").unwrap().as_i64().unwrap(), 1);
        assert_eq!(s[0].1.get("models_live").unwrap().as_i64().unwrap(), 2);
    }
}
