//! The cluster front-router: a [`Dispatch`] implementation that speaks
//! protocol v2 *client-side* to a static set of backend nodes.
//!
//! Placement is consistent hashing over the request's model spec (see
//! [`super::ring`]): every model owns a primary plus `replication - 1`
//! fallback nodes, and the router walks that preference order. The
//! seed-resolution contract makes the whole data path idempotent — the
//! node-side wire layer the router sits behind resolves `seed` before
//! dispatch, so a hedged or failed-over reissue carries the exact same
//! seed and every replica computes bit-identical logits.
//!
//! Three recovery mechanisms compose per request:
//!
//! * **Failover** — a transport error (connect refused, connection
//!   dropped) marks the node toward `Down` and moves to the next
//!   replica. Clean application errors (`[code] ...` wire errors,
//!   overload rejections) are returned to the caller untouched: they
//!   are deterministic and would repeat on every replica.
//! * **Replication on demand** — a `not in manifest` answer makes the
//!   router find the artifact on any other node (`list_models` →
//!   `pull_artifact` by digest), re-verify the digest locally, push it
//!   to the missing node at the same version, and retry. Nodes never
//!   talk to each other; the router mediates.
//! * **Hedged retries** — single-row requests that outlive a
//!   quantile-derived delay ([`super::hedge`]) are reissued to the next
//!   replica; the first answer wins and the loser is discarded (its
//!   connection is returned to the pool once it drains — the v2
//!   pipelining protocol stashes the stale response harmlessly). Batch
//!   requests fail over but never hedge: a duplicate batch doubles
//!   load for a latency win only its slowest row would see.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::sync::LockExt;
use crate::client::{CallOptions, Inference, KanClient};
use crate::coordinator::backend::RowOutput;
use crate::coordinator::protocol::ModelSummary;
use crate::coordinator::scheduler::ClientId;
use crate::coordinator::server::{Dispatch, RouteSpec};
use crate::error::{Error, Result};
use crate::obs::trace::Stage;
use crate::registry::{digest, parse_model_spec};
use crate::util::json::{obj, Value};

use super::hedge::HedgePolicy;
use super::membership::{Membership, NodeState};
use super::ring::HashRing;

/// Idle pooled connections kept per node.
const POOL_CAP: usize = 8;

/// Monotone counters for the `cluster` metrics section.
#[derive(Default)]
struct Counters {
    forwards: AtomicU64,
    hedges: AtomicU64,
    hedge_wins: AtomicU64,
    hedge_cancellations: AtomicU64,
    failovers: AtomicU64,
    replications: AtomicU64,
    replication_failures: AtomicU64,
}

/// Tuning for [`ClusterRouter::new`] (see `config::ClusterConfig` for
/// the file side and the defaults).
#[derive(Debug, Clone)]
pub struct RouterOptions {
    /// Replicas per model spec (primary included).
    pub replication: usize,
    /// Virtual nodes per physical node on the hash ring.
    pub vnodes: usize,
    /// Heartbeat probe period; `0` disables the background loop
    /// (data-path failures still drive membership).
    pub heartbeat_ms: u64,
    /// Consecutive failures before a node is marked `Down`.
    pub fail_after: u32,
    /// Master switch for hedged retries.
    pub hedge: bool,
    /// Latency quantile the hedge delay is derived from.
    pub hedge_quantile: f64,
    /// Clamp on the derived hedge delay.
    pub hedge_min_ms: u64,
    pub hedge_max_ms: u64,
}

impl Default for RouterOptions {
    fn default() -> Self {
        Self {
            replication: 2,
            vnodes: 64,
            heartbeat_ms: 500,
            fail_after: 2,
            hedge: true,
            hedge_quantile: 0.9,
            hedge_min_ms: 1,
            hedge_max_ms: 100,
        }
    }
}

/// One completed remote attempt, as reported by its worker thread.
struct Attempt {
    node: usize,
    hedge: bool,
    result: Result<Inference>,
}

/// The router itself. Construct with [`ClusterRouter::new`], wrap in an
/// `Arc`, and hand it to [`crate::coordinator::TcpServer`] — clients
/// then talk to the cluster exactly as they would to a single node.
pub struct ClusterRouter {
    ring: HashRing,
    members: Arc<Membership>,
    pools: Vec<Arc<Mutex<Vec<KanClient>>>>,
    opts: RouterOptions,
    hedge: HedgePolicy,
    counters: Counters,
    stop: Arc<AtomicBool>,
}

impl ClusterRouter {
    /// Build the ring + membership over `nodes` (host:port strings, the
    /// order defines ring identity) and start the heartbeat loop.
    pub fn new(nodes: Vec<String>, opts: RouterOptions) -> Result<Arc<ClusterRouter>> {
        if nodes.is_empty() {
            return Err(Error::Config(
                "cluster router needs at least one node (cluster.nodes)".into(),
            ));
        }
        let members = Arc::new(Membership::new(nodes.clone(), opts.fail_after));
        let router = Arc::new(ClusterRouter {
            ring: HashRing::new(&nodes, opts.vnodes),
            pools: nodes.iter().map(|_| Arc::new(Mutex::new(Vec::new()))).collect(),
            hedge: HedgePolicy::new(
                opts.hedge_quantile,
                opts.hedge_min_ms,
                opts.hedge_max_ms,
            ),
            members: members.clone(),
            counters: Counters::default(),
            stop: Arc::new(AtomicBool::new(false)),
            opts,
        });
        if router.opts.heartbeat_ms > 0 {
            spawn_heartbeat(
                members,
                Duration::from_millis(router.opts.heartbeat_ms),
                router.stop.clone(),
            );
        }
        Ok(router)
    }

    /// Membership view (tests and the CLI use this to drain nodes).
    pub fn membership(&self) -> &Membership {
        &self.members
    }

    /// Ring placement for a model spec, preference order (tests use
    /// this to aim traffic at a known replica set).
    pub fn placement(&self, key: &str) -> Vec<usize> {
        self.ring.replicas(key, self.opts.replication)
    }

    // ---- connection pool -------------------------------------------------

    fn checkout(&self, node: usize) -> Result<KanClient> {
        if let Some(c) = self.pools[node].lock_recover().pop() {
            return Ok(c);
        }
        KanClient::connect(self.members.addr(node))
    }

    fn put_back(&self, node: usize, client: KanClient) {
        put_back_pool(&self.pools[node], client);
    }

    // ---- single-row data path (hedged) -------------------------------------

    /// Spawn one remote attempt; the worker owns the connection for the
    /// call's duration and reports through `tx` (a dropped receiver —
    /// the caller already got a winner — is fine).
    fn spawn_attempt(
        &self,
        node: usize,
        model: Option<String>,
        features: Vec<f32>,
        call: CallOptions,
        hedge: bool,
        tx: mpsc::Sender<Attempt>,
    ) {
        let pool = self.pools[node].clone();
        let addr = self.members.addr(node).to_string();
        std::thread::spawn(move || {
            let mut client = {
                let pooled = pool.lock_recover().pop();
                match pooled.map(Ok).unwrap_or_else(|| KanClient::connect(&addr)) {
                    Ok(c) => c,
                    Err(e) => {
                        let _ = tx.send(Attempt { node, hedge, result: Err(e) });
                        return;
                    }
                }
            };
            let result = client.infer_opts(model.as_deref(), &features, &call);
            if result.is_ok() || is_remote_app_error(result.as_ref().err()) {
                put_back_pool(&pool, client);
            }
            let _ = tx.send(Attempt { node, hedge, result });
        });
    }

    fn route_candidates(&self, key: &str) -> Result<Vec<usize>> {
        let pref = self.ring.replicas(key, self.opts.replication);
        let up: Vec<usize> = pref.into_iter().filter(|&i| self.members.is_routable(i)).collect();
        if up.is_empty() {
            return Err(Error::Serving(format!(
                "no routable cluster node for '{key}' ({} configured, {} up)",
                self.members.len(),
                self.members.up_count()
            )));
        }
        Ok(up)
    }

    // ---- replication -------------------------------------------------------

    /// Find `spec`'s artifact on any non-down node other than `target`,
    /// verify it locally, and push it to `target` at the source's
    /// version. Draining nodes still serve as sources.
    fn replicate_to(&self, target: usize, spec: Option<&str>) -> Result<()> {
        let spec = spec.ok_or_else(|| {
            Error::Serving(
                "cannot replicate: the request named no model (default-model \
                 requests need the artifact pre-published on every replica)"
                    .into(),
            )
        })?;
        let (name, want_version) = parse_model_spec(spec)?;
        let mut last_err: Option<Error> = None;
        for src in 0..self.members.len() {
            if src == target || self.members.state(src) == NodeState::Down {
                continue;
            }
            match self.replicate_from(src, target, name, want_version) {
                Ok(found) => {
                    if found {
                        self.counters.replications.fetch_add(1, Ordering::Relaxed);
                        return Ok(());
                    }
                }
                Err(e) => last_err = Some(e),
            }
        }
        self.counters.replication_failures.fetch_add(1, Ordering::Relaxed);
        Err(last_err.unwrap_or_else(|| {
            Error::Serving(format!("no cluster node has an artifact for model '{spec}'"))
        }))
    }

    /// One source candidate: `Ok(false)` means "this node does not have
    /// it, try the next"; errors mean the transfer itself failed.
    fn replicate_from(
        &self,
        src: usize,
        target: usize,
        name: &str,
        want_version: Option<u32>,
    ) -> Result<bool> {
        let mut from = self.checkout(src)?;
        let models = match from.list_models() {
            Ok(m) => m,
            Err(e) => {
                self.members.record_failure(src);
                return Err(e);
            }
        };
        let found = models
            .into_iter()
            .find(|m| m.name == name && want_version.map_or(true, |v| v == m.version));
        let Some(summary) = found else {
            self.put_back(src, from);
            return Ok(false);
        };
        let Some(dig) = summary.digest.clone() else {
            self.put_back(src, from);
            return Ok(false);
        };
        let (data, _meta) = from.pull_artifact(&dig)?;
        self.put_back(src, from);
        // end-to-end integrity: re-hash what actually crossed the wire
        let actual = digest::digest_bytes(&data);
        if actual != dig {
            return Err(Error::Registry(format!(
                "digest mismatch pulling '{name}' from {}: source says {dig}, \
                 payload is {actual} (artifact corrupted in transit?)",
                self.members.addr(src)
            )));
        }
        let mut to = self.checkout(target)?;
        let result = to.push_artifact(name, Some(summary.version), &data);
        if result.is_ok() || is_remote_app_error(result.as_ref().err()) {
            self.put_back(target, to);
        }
        result.map(|_| true)
    }

    // ---- metrics rollup ----------------------------------------------------

    /// Fetch one routable node's `metrics` body, if reachable.
    fn node_metrics(&self, node: usize) -> Option<Value> {
        let mut c = self.checkout(node).ok()?;
        match c.metrics() {
            Ok(body) => {
                self.put_back(node, c);
                Some(body)
            }
            Err(_) => None,
        }
    }

    // ---- rollout control ---------------------------------------------------

    /// Forward one rollout control verb to the shard that owns `name`.
    /// Placement keys on the *bare* model name — the same key default
    /// (unversioned) inference traffic hashes to — so the shard running
    /// the rollout is the shard splitting the traffic. Transport errors
    /// fail over along the replica preference order; clean application
    /// errors come back untouched (they are deterministic).
    fn forward_rollout(
        &self,
        name: &str,
        mut call: impl FnMut(&mut KanClient) -> Result<Value>,
    ) -> Result<Value> {
        let candidates = self.route_candidates(name)?;
        self.counters.forwards.fetch_add(1, Ordering::Relaxed);
        let mut last_err: Option<Error> = None;
        for (i, &node) in candidates.iter().enumerate() {
            let mut client = match self.checkout(node) {
                Ok(c) => c,
                Err(e) => {
                    self.members.record_failure(node);
                    last_err = Some(e);
                    if i + 1 < candidates.len() {
                        self.counters.failovers.fetch_add(1, Ordering::Relaxed);
                    }
                    continue;
                }
            };
            match call(&mut client) {
                Ok(body) => {
                    self.put_back(node, client);
                    return Ok(body);
                }
                Err(e) if is_remote_app_error(Some(&e)) => {
                    self.put_back(node, client);
                    return Err(e);
                }
                Err(e) => {
                    self.members.record_failure(node);
                    last_err = Some(e);
                    if i + 1 < candidates.len() {
                        self.counters.failovers.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        Err(last_err.unwrap_or_else(|| Error::Serving("no cluster replica answered".into())))
    }
}

impl Drop for ClusterRouter {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn put_back_pool(pool: &Mutex<Vec<KanClient>>, client: KanClient) {
    let mut p = pool.lock_recover();
    if p.len() < POOL_CAP {
        p.push(client);
    }
}

/// A clean remote application error: the node answered a well-formed
/// wire error (`[code] ...` rendering, or the typed overload). The
/// connection is still healthy and the error is deterministic — every
/// replica would repeat it.
fn is_remote_app_error(e: Option<&Error>) -> bool {
    match e {
        None => false,
        Some(Error::Overloaded { .. }) => true,
        Some(Error::Serving(m)) => m.starts_with('['),
        Some(_) => false,
    }
}

/// Does this error mean "the node does not have the model" (and
/// replication could fix it)?
fn is_missing_model(e: &Error) -> bool {
    matches!(e, Error::Serving(m) if m.starts_with("[not_found]") && m.contains("not in manifest"))
}

fn heartbeat_node(members: &Membership, idx: usize) {
    let probe = KanClient::connect(members.addr(idx)).and_then(|mut c| c.health_node());
    match probe {
        Ok((_, models_live, node_id, uptime_s)) => {
            members.record_ok(idx, node_id, models_live, uptime_s);
        }
        Err(_) => {
            members.record_failure(idx);
        }
    }
}

/// Background liveness loop. Heartbeat connections are throwaway on
/// purpose: probing the ability to *connect* is the point, a pooled
/// connection would keep reporting a node healthy after it stopped
/// accepting.
fn spawn_heartbeat(members: Arc<Membership>, period: Duration, stop: Arc<AtomicBool>) {
    let spawned = std::thread::Builder::new()
        .name("kan-edge-heartbeat".into())
        .spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                for idx in 0..members.len() {
                    heartbeat_node(&members, idx);
                }
                std::thread::sleep(period);
            }
        });
    // the heartbeat is an optimization: data-path failures also drive
    // membership state, so a failed spawn degrades liveness detection
    // instead of taking the router down
    if let Err(e) = spawned {
        crate::obs::log::warn(
            "cluster",
            &format!("heartbeat thread failed to spawn ({e}); relying on data-path failures"),
        );
    }
}

impl Dispatch for ClusterRouter {
    /// Route one row. The trace stages are reinterpreted as route hops
    /// (`docs/OBSERVABILITY.md`): admission = replica selection, queue =
    /// primary issued, batch = hedge window closed, execute = winning
    /// answer arrived; respond stays with the router's own wire layer.
    fn dispatch(
        &self,
        _client: ClientId,
        route: &RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, RowOutput)> {
        let started = Instant::now();
        let key = route.model.clone().unwrap_or_default();
        let candidates = self.route_candidates(&key)?;
        if let Some(t) = &route.trace {
            t.mark(Stage::Admission);
        }
        self.counters.forwards.fetch_add(1, Ordering::Relaxed);
        let call = CallOptions {
            backend: route.backend,
            seed: route.opts.seed,
            trials: route.opts.trials,
            retry_overloaded: false,
        };

        let (tx, rx) = mpsc::channel();
        self.spawn_attempt(
            candidates[0],
            route.model.clone(),
            features.clone(),
            call,
            false,
            tx.clone(),
        );
        if let Some(t) = &route.trace {
            t.mark(Stage::Queue);
        }
        let mut in_flight = 1usize;
        let mut next = 1usize;
        let mut hedged = false;
        let mut replicated = false;
        let mut hedge_window_open = route.trace.is_some();
        let mut last_err: Option<Error> = None;

        loop {
            let attempt = if in_flight > 0
                && self.opts.hedge
                && !hedged
                && next < candidates.len()
            {
                match rx.recv_timeout(self.hedge.delay()) {
                    Ok(a) => a,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        hedged = true;
                        self.counters.hedges.fetch_add(1, Ordering::Relaxed);
                        self.spawn_attempt(
                            candidates[next],
                            route.model.clone(),
                            features.clone(),
                            call,
                            true,
                            tx.clone(),
                        );
                        next += 1;
                        in_flight += 1;
                        if let Some(t) = &route.trace {
                            t.mark(Stage::Batch);
                        }
                        hedge_window_open = false;
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            } else if in_flight > 0 {
                match rx.recv() {
                    Ok(a) => a,
                    Err(_) => break,
                }
            } else if next < candidates.len() {
                self.counters.failovers.fetch_add(1, Ordering::Relaxed);
                self.spawn_attempt(
                    candidates[next],
                    route.model.clone(),
                    features.clone(),
                    call,
                    false,
                    tx.clone(),
                );
                next += 1;
                in_flight += 1;
                continue;
            } else {
                break;
            };
            in_flight -= 1;

            match attempt.result {
                Ok(inf) => {
                    if attempt.hedge {
                        self.counters.hedge_wins.fetch_add(1, Ordering::Relaxed);
                    } else if hedged {
                        self.counters
                            .hedge_cancellations
                            .fetch_add(1, Ordering::Relaxed);
                    }
                    self.hedge.record(started.elapsed());
                    if let Some(t) = &route.trace {
                        if hedge_window_open {
                            t.mark(Stage::Batch);
                        }
                        t.mark(Stage::Execute);
                    }
                    return Ok((
                        inf.model,
                        RowOutput { logits: inf.logits, trial_std: inf.std },
                    ));
                }
                Err(e) if is_missing_model(&e) && !replicated => {
                    replicated = true;
                    match self.replicate_to(attempt.node, route.model.as_deref()) {
                        Ok(()) => {
                            self.spawn_attempt(
                                attempt.node,
                                route.model.clone(),
                                features.clone(),
                                call,
                                attempt.hedge,
                                tx.clone(),
                            );
                            in_flight += 1;
                        }
                        Err(rep) => last_err = Some(rep),
                    }
                }
                Err(e) if is_remote_app_error(Some(&e)) => return Err(e),
                Err(e) => {
                    self.members.record_failure(attempt.node);
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| Error::Serving("no cluster replica answered".into())))
    }

    /// Batch routing: same placement and failover as single rows, but
    /// never hedged (a duplicated batch doubles backend load; its
    /// latency is dominated by the slowest row either way).
    fn dispatch_batch(
        &self,
        _client: ClientId,
        route: &RouteSpec,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<RowOutput>)> {
        let key = route.model.clone().unwrap_or_default();
        let candidates = self.route_candidates(&key)?;
        self.counters.forwards.fetch_add(1, Ordering::Relaxed);
        let call = CallOptions {
            backend: route.backend,
            seed: route.opts.seed,
            trials: route.opts.trials,
            retry_overloaded: false,
        };
        let mut replicated = false;
        let mut last_err: Option<Error> = None;
        let mut i = 0usize;
        while i < candidates.len() {
            let node = candidates[i];
            let mut client = match self.checkout(node) {
                Ok(c) => c,
                Err(e) => {
                    self.members.record_failure(node);
                    last_err = Some(e);
                    i += 1;
                    if i < candidates.len() {
                        self.counters.failovers.fetch_add(1, Ordering::Relaxed);
                    }
                    continue;
                }
            };
            match client.infer_batch_opts(route.model.as_deref(), rows.clone(), &call) {
                Ok((model, wire_rows)) => {
                    self.put_back(node, client);
                    let outs = wire_rows
                        .into_iter()
                        .map(|w| RowOutput { logits: w.logits, trial_std: w.std })
                        .collect();
                    return Ok((model, outs));
                }
                Err(e) if is_missing_model(&e) && !replicated => {
                    self.put_back(node, client);
                    replicated = true;
                    match self.replicate_to(node, route.model.as_deref()) {
                        Ok(()) => continue, // retry the same node
                        Err(rep) => {
                            last_err = Some(rep);
                            i += 1;
                        }
                    }
                }
                Err(e) if is_remote_app_error(Some(&e)) => {
                    self.put_back(node, client);
                    return Err(e);
                }
                Err(e) => {
                    self.members.record_failure(node);
                    last_err = Some(e);
                    i += 1;
                    if i < candidates.len() {
                        self.counters.failovers.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
        Err(last_err.unwrap_or_else(|| Error::Serving("no cluster replica answered".into())))
    }

    /// Union of every routable node's models, deduplicated by
    /// `name@version` (a replicated model reports once, `live` if live
    /// anywhere).
    fn model_summaries(&self) -> Vec<ModelSummary> {
        let mut seen: BTreeMap<String, ModelSummary> = BTreeMap::new();
        for node in 0..self.members.len() {
            if !self.members.is_routable(node) {
                continue;
            }
            let Ok(mut c) = self.checkout(node) else { continue };
            let Ok(models) = c.list_models() else { continue };
            self.put_back(node, c);
            for m in models {
                let key = format!("{}@{}", m.name, m.version);
                match seen.get_mut(&key) {
                    Some(prev) => prev.live = prev.live || m.live,
                    None => {
                        seen.insert(key, m);
                    }
                }
            }
        }
        seen.into_values().collect()
    }

    /// Cluster rollup merged into the router's `metrics` body:
    ///
    /// * `"cluster"` — router-side counters (forwards, hedges/wins/
    ///   cancellations, failovers, replications) plus membership gauges.
    /// * `"nodes"` — one flat object per node, keyed by its reported
    ///   `node_id` (address until known): liveness plus that node's
    ///   summed request/error counters.
    /// * `"models"` — per-serving-id counters summed *exactly* across
    ///   nodes (integer counters only; a replicated model's traffic
    ///   adds up across its replicas).
    fn metrics_overlay(&self) -> Option<Value> {
        let c = &self.counters;
        let cluster = obj(vec![
            ("nodes_configured", Value::Int(self.members.len() as i64)),
            ("nodes_up", Value::Int(self.members.up_count() as i64)),
            ("replication_factor", Value::Int(self.opts.replication as i64)),
            ("forwards", Value::Int(c.forwards.load(Ordering::Relaxed) as i64)),
            ("hedges", Value::Int(c.hedges.load(Ordering::Relaxed) as i64)),
            ("hedge_wins", Value::Int(c.hedge_wins.load(Ordering::Relaxed) as i64)),
            (
                "hedge_cancellations",
                Value::Int(c.hedge_cancellations.load(Ordering::Relaxed) as i64),
            ),
            ("hedge_delay_ms", Value::Int(self.hedge.delay().as_millis() as i64)),
            ("failovers", Value::Int(c.failovers.load(Ordering::Relaxed) as i64)),
            ("replications", Value::Int(c.replications.load(Ordering::Relaxed) as i64)),
            (
                "replication_failures",
                Value::Int(c.replication_failures.load(Ordering::Relaxed) as i64),
            ),
        ]);

        let mut nodes: BTreeMap<String, Value> = BTreeMap::new();
        let mut model_sums: BTreeMap<String, BTreeMap<String, i64>> = BTreeMap::new();
        for (node, (label, status)) in self.members.summaries().into_iter().enumerate() {
            let mut flat: BTreeMap<String, Value> = match status {
                Value::Object(m) => m,
                other => {
                    let mut m = BTreeMap::new();
                    m.insert("status".to_string(), other);
                    m
                }
            };
            if self.members.is_routable(node) {
                if let Some(body) = self.node_metrics(node) {
                    let mut requests = 0i64;
                    let mut errors = 0i64;
                    if let Some(models) = body.get("models").and_then(Value::as_object) {
                        for (mid, report) in models {
                            let Some(fields) = report.as_object() else { continue };
                            let sums = model_sums.entry(mid.clone()).or_default();
                            for (k, v) in fields {
                                if let Value::Int(i) = v {
                                    *sums.entry(k.clone()).or_insert(0) += i;
                                }
                            }
                            requests += fields
                                .get("requests")
                                .and_then(|v| v.as_i64())
                                .unwrap_or(0);
                            errors += fields
                                .get("errors")
                                .and_then(|v| v.as_i64())
                                .unwrap_or(0);
                        }
                    }
                    flat.insert("requests".to_string(), Value::Int(requests));
                    flat.insert("errors".to_string(), Value::Int(errors));
                }
            }
            nodes.insert(label, Value::Object(flat));
        }

        let models = Value::Object(
            model_sums
                .into_iter()
                .map(|(mid, fields)| {
                    (
                        mid,
                        Value::Object(
                            fields
                                .into_iter()
                                .map(|(k, v)| (k, Value::Int(v)))
                                .collect(),
                        ),
                    )
                })
                .collect(),
        );

        Some(obj(vec![
            ("cluster", cluster),
            ("nodes", Value::Object(nodes)),
            ("models", models),
        ]))
    }

    /// Start a rollout on the shard owning the candidate's model name.
    fn rollout_start(&self, model: &str, baseline: &str) -> Result<Value> {
        let (name, _) = parse_model_spec(model)?;
        self.forward_rollout(name, |c| c.rollout_start(model, baseline))
    }

    /// Named status goes to the owning shard; the unnamed form fans out
    /// to every routable node and merges each shard's `rollouts` map
    /// (names are globally unique — one shard owns each rollout).
    fn rollout_status(&self, model: Option<&str>) -> Result<Value> {
        if let Some(spec) = model {
            let (name, _) = parse_model_spec(spec)?;
            return self.forward_rollout(name, |c| c.rollout_status(Some(spec)));
        }
        let mut merged: BTreeMap<String, Value> = BTreeMap::new();
        let mut reachable = 0usize;
        for node in 0..self.members.len() {
            if !self.members.is_routable(node) {
                continue;
            }
            let Ok(mut c) = self.checkout(node) else { continue };
            if let Ok(body) = c.rollout_status(None) {
                self.put_back(node, c);
                reachable += 1;
                if let Some(ro) = body.get("rollouts").and_then(Value::as_object) {
                    for (k, v) in ro {
                        merged.insert(k.clone(), v.clone());
                    }
                }
            }
        }
        if reachable == 0 {
            return Err(Error::Serving(format!(
                "no routable cluster node answered rollout_status ({} configured, {} up)",
                self.members.len(),
                self.members.up_count()
            )));
        }
        Ok(obj(vec![("rollouts", Value::Object(merged))]))
    }

    fn rollout_abort(&self, model: &str) -> Result<Value> {
        let (name, _) = parse_model_spec(model)?;
        self.forward_rollout(name, |c| c.rollout_abort(model))
    }

    fn rollout_clear(&self, model: &str) -> Result<Value> {
        let (name, _) = parse_model_spec(model)?;
        self.forward_rollout(name, |c| c.rollout_clear(model))
    }
}
