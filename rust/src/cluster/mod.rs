//! Sharded multi-node serving (`docs/CLUSTER.md`).
//!
//! A [`ClusterRouter`] is a [`crate::coordinator::Dispatch`] that owns
//! no models itself: it places each `name@version` on a replica set via
//! a consistent-hash [`ring`], forwards the v2 verbs to the owning
//! nodes over pooled [`crate::client::KanClient`] connections, tracks
//! per-node liveness ([`membership`], fed by a heartbeat loop), hedges
//! slow single-row requests ([`hedge`]), and replicates missing
//! artifacts on demand through the `pull_artifact` / `push_artifact`
//! verbs. Served behind the ordinary [`crate::coordinator::TcpServer`],
//! the cluster is indistinguishable from a single node to clients.

pub mod hedge;
pub mod membership;
pub mod ring;
pub mod router;

pub use hedge::HedgePolicy;
pub use membership::{Membership, NodeState};
pub use ring::HashRing;
pub use router::{ClusterRouter, RouterOptions};
