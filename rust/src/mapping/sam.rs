//! KAN-SAM: sparsity-aware weight mapping (paper §3.3, Fig 8/12).
//!
//! Rows with high activation probability (`B_H(X)`) are programmed into
//! RRAM cells *near the BL clamping circuit*, where IR-drop attenuation is
//! smallest; low-probability rows (`B_L(X)`) go far from the clamp. No
//! hardware or algorithm changes — just a permutation chosen at mapping
//! time, which is the paper's point.
//!
//! When a layer spans several tiles, physical slots are ranked by their
//! in-tile distance to the clamp (slot `s` of any tile is distance `s %
//! tile_rows`), so every tile gets its hottest rows nearest its own clamp.


/// Mapping strategies for placing logical rows onto physical slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MappingStrategy {
    /// Natural order (the Fig 12 baseline: "uniformly mapped ... without
    /// considering Bi(X) activation probabilities").
    Uniform,
    /// KAN-SAM: highest activation probability nearest the clamp.
    Sam,
    /// Adversarial order (highest probability farthest) — used by ablation
    /// benches to bound the effect size.
    WorstCase,
}

/// Build the row mapping for one layer.
///
/// `probs[r]` = activation probability / expected drive of logical row `r`;
/// `tile_rows` = physical array size. Returns `mapping[slot] = logical row`
/// with slots filled tile-by-tile (slot 0 of each tile nearest its clamp).
pub fn build_mapping(probs: &[f64], tile_rows: usize, strategy: MappingStrategy) -> Vec<usize> {
    let n = probs.len();
    match strategy {
        MappingStrategy::Uniform => (0..n).collect(),
        MappingStrategy::Sam | MappingStrategy::WorstCase => {
            // logical rows by probability (desc for SAM, asc for worst case)
            let mut rows: Vec<usize> = (0..n).collect();
            rows.sort_by(|&a, &b| {
                probs[b]
                    .partial_cmp(&probs[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b)) // deterministic tie-break
            });
            if strategy == MappingStrategy::WorstCase {
                rows.reverse();
            }
            // physical slots by distance from their tile's clamp
            let mut slots: Vec<usize> = (0..n).collect();
            slots.sort_by_key(|&s| (s % tile_rows, s / tile_rows));
            let mut mapping = vec![0usize; n];
            for (rank, &slot) in slots.iter().enumerate() {
                mapping[slot] = rows[rank];
            }
            mapping
        }
    }
}

/// Validity check: a mapping must be a permutation of `0..n`.
pub fn is_permutation(mapping: &[usize]) -> bool {
    let n = mapping.len();
    let mut seen = vec![false; n];
    for &m in mapping {
        if m >= n || seen[m] {
            return false;
        }
        seen[m] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_identity() {
        let probs = vec![0.5, 0.1, 0.9];
        assert_eq!(build_mapping(&probs, 8, MappingStrategy::Uniform), vec![0, 1, 2]);
    }

    #[test]
    fn sam_places_hottest_row_at_clamp() {
        let probs = vec![0.1, 0.9, 0.3, 0.6];
        let m = build_mapping(&probs, 8, MappingStrategy::Sam);
        // single tile: slot 0 gets the hottest logical row (1)
        assert_eq!(m[0], 1);
        assert_eq!(m[1], 3);
        assert_eq!(m[2], 2);
        assert_eq!(m[3], 0);
        assert!(is_permutation(&m));
    }

    #[test]
    fn worst_case_is_reverse_of_sam_ranking() {
        let probs = vec![0.1, 0.9, 0.3, 0.6];
        let sam = build_mapping(&probs, 8, MappingStrategy::Sam);
        let worst = build_mapping(&probs, 8, MappingStrategy::WorstCase);
        assert_eq!(sam[0], worst[3]);
        assert_eq!(sam[3], worst[0]);
    }

    #[test]
    fn multi_tile_fills_clamp_slots_first() {
        // 6 rows, tiles of 2: slots 0,2,4 are each tile's clamp-nearest;
        // the three hottest rows must land there
        let probs = vec![0.6, 0.1, 0.9, 0.2, 0.8, 0.3];
        let m = build_mapping(&probs, 2, MappingStrategy::Sam);
        let clamp_rows: Vec<usize> = vec![m[0], m[2], m[4]];
        assert!(clamp_rows.contains(&2)); // p=0.9
        assert!(clamp_rows.contains(&4)); // p=0.8
        assert!(clamp_rows.contains(&0)); // p=0.6
        assert!(is_permutation(&m));
    }

    #[test]
    fn deterministic_under_ties() {
        let probs = vec![0.5; 10];
        let a = build_mapping(&probs, 4, MappingStrategy::Sam);
        let b = build_mapping(&probs, 4, MappingStrategy::Sam);
        assert_eq!(a, b);
        assert!(is_permutation(&a));
    }
}
