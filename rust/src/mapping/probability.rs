//! B(X) activation-probability estimation (paper §3.3).
//!
//! For degree-K splines only K+1 basis functions fire per input, so each
//! crossbar row `(input i, basis g)` has an activation probability
//! determined by the input's distribution over knot intervals. KAN-SAM
//! ranks rows by this probability. Two estimators are provided:
//!
//! * [`empirical`] — count interval occupancy over a calibration sample
//!   (what a deployment would do);
//! * [`gaussian`] — the analytic closed form the paper's Fig 8 illustrates,
//!   for a Gaussian input over the grid range.

use crate::kan::layer::QuantKanLayer;

/// Empirical per-row activation statistics for one layer.
///
/// Returns `prob[i * (G+K) + g]` = expected WL drive (mean B value, in
/// [0, 1]) of row `(i, g)` over the calibration inputs. Using the *expected
/// drive* rather than the on/off frequency weights frequently-hit, strongly
/// driven rows highest — those carry the most charge and therefore matter
/// most under IR-drop.
///
/// Calibration rows arrive as `f64`: the caller propagates activations
/// through the digital reference without any `f32` truncation, so the
/// interval occupancy counted here matches the codes serving computes
/// (an `f32` round trip is a double rounding that can flip a code at a
/// level boundary).
pub fn empirical<'a>(
    layer: &QuantKanLayer,
    calib: impl Iterator<Item = &'a [f64]>,
) -> Vec<f64> {
    let nb = layer.spec.num_basis();
    let mut acc = vec![0.0f64; layer.din * nb];
    let mut n = 0usize;
    for row in calib {
        assert_eq!(row.len(), layer.din);
        let xq: Vec<u32> = row.iter().map(|&v| layer.spec.quantize(v)).collect();
        let drives = layer.wordline_drives(&xq);
        for (slot, &d) in drives.iter().enumerate() {
            acc[slot] += d as f64 / 255.0;
        }
        n += 1;
    }
    if n > 0 {
        for a in &mut acc {
            *a /= n as f64;
        }
    }
    acc
}

/// Analytic activation probability for a Gaussian input `N(mu, sigma²)`
/// over the layer's grid: probability that basis `g` is active = P(x lands
/// in one of the K+1 intervals that basis covers).
pub fn gaussian(layer: &QuantKanLayer, mu: f64, sigma: f64) -> Vec<f64> {
    let spec = &layer.spec;
    let nb = spec.num_basis();
    let h = spec.knot_spacing();
    let k = spec.k as i64;
    let mut probs = vec![0.0f64; layer.din * nb];
    for g in 0..nb as i64 {
        // basis g is active on grid intervals [g-K, g] ∩ [0, G-1]
        let lo_iv = (g - k).max(0);
        let hi_iv = g.min(spec.g as i64 - 1);
        let mut p = 0.0;
        for iv in lo_iv..=hi_iv {
            let a = spec.lo + iv as f64 * h;
            let b = a + h;
            p += normal_cdf((b - mu) / sigma) - normal_cdf((a - mu) / sigma);
        }
        for i in 0..layer.din {
            probs[i * nb + g as usize] = p;
        }
    }
    probs
}

/// Φ(x): standard normal CDF via the erf-like Abramowitz–Stegun 7.1.26
/// approximation (|error| < 7.5e-8 — plenty for a ranking).
pub fn normal_cdf(x: f64) -> f64 {
    let t = 1.0 / (1.0 + 0.2316419 * x.abs());
    let d = 0.3989422804014327 * (-x * x / 2.0).exp();
    let poly = t
        * (0.319381530
            + t * (-0.356563782 + t * (1.781477937 + t * (-1.821255978 + t * 1.330274429))));
    let p = 1.0 - d * poly;
    if x >= 0.0 {
        p
    } else {
        1.0 - p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::layer::tests::toy_layer;

    #[test]
    fn normal_cdf_sanity() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-1.96) - 0.025).abs() < 1e-3);
    }

    #[test]
    fn gaussian_probs_peak_at_center() {
        let layer = toy_layer(8, 3, 1, 1);
        let probs = gaussian(&layer, 0.0, 0.3); // grid spans [-1, 1]
        let nb = layer.spec.num_basis();
        let center = nb / 2;
        let peak = probs[..nb]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(
            (peak as i64 - center as i64).abs() <= 1,
            "peak at {peak}, expected near {center}"
        );
        // extremes least likely (Fig 8)
        assert!(probs[0] < probs[center]);
        assert!(probs[nb - 1] < probs[center]);
    }

    #[test]
    fn empirical_matches_structure() {
        let layer = toy_layer(5, 3, 2, 1);
        // calibration set concentrated near x = 0 (grid center)
        let calib: Vec<Vec<f64>> = (0..200)
            .map(|i| vec![0.05 * ((i % 9) as f64 - 4.0) / 4.0; 2])
            .collect();
        let probs = empirical(&layer, calib.iter().map(|r| r.as_slice()));
        let nb = layer.spec.num_basis();
        // central rows should dominate extreme rows for both inputs
        for i in 0..2 {
            let row = &probs[i * nb..(i + 1) * nb];
            let center_mass: f64 = row[2..=5].iter().sum();
            let edge_mass: f64 = row[0] + row[nb - 1];
            assert!(center_mass > edge_mass, "input {i}: {row:?}");
        }
    }

    #[test]
    fn empirical_handles_empty_calibration() {
        let layer = toy_layer(5, 3, 2, 1);
        let probs = empirical(&layer, std::iter::empty::<&[f64]>());
        assert!(probs.iter().all(|&p| p == 0.0));
    }
}
