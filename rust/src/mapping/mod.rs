//! KAN-SAM sparsity-aware weight mapping (paper §3.3).
//!
//! * [`probability`] — B(X) activation-probability estimation (empirical
//!   over a calibration set, or the analytic Gaussian form of Fig 8).
//! * [`sam`] — the mapping itself: a permutation placing hot rows near the
//!   BL clamp, plus the uniform baseline and an adversarial ablation.

pub mod probability;
pub mod sam;

pub use probability::{empirical, gaussian};
pub use sam::{build_mapping, is_permutation, MappingStrategy};
