//! Observability integration tests: end-to-end request tracing over a
//! live TCP server (every stage stamped, durations partition the
//! total), the bounded-memory contract of the trace ring under
//! sustained load, the Prometheus exposition plane agreeing with the
//! JSON metrics plane, and the engine-profiling bit-parity guarantee.
//! Fully offline (synthetic KAN checkpoints published into temp
//! registries).

#![allow(clippy::field_reassign_with_default)]

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kan_edge::client::KanClient;
use kan_edge::config::AppConfig;
use kan_edge::coordinator::router::trace_hub;
use kan_edge::coordinator::{tcp_limits, Dispatch, TcpServer};
use kan_edge::kan::checkpoint::synthetic_kan_checkpoint;
use kan_edge::obs::trace::{Stage, TraceHub};
use kan_edge::registry::{ModelManifest, ModelRegistry};
use kan_edge::util::json::Value;

mod common;

const STAGE_NAMES: [&str; 5] = ["admission", "queue", "batch", "execute", "respond"];

fn tmp_dir(test: &str) -> PathBuf {
    common::tmp_dir("kan_edge_obs_tests", test)
}

/// Publish a synthetic KAN with real (nonzero) spline mass as model "m"
/// into a fresh registry dir.
fn publish_dense_model(dir: &Path, cfg: &AppConfig) -> Arc<ModelRegistry> {
    ModelManifest::empty().save(dir).unwrap();
    let registry = ModelRegistry::open(cfg).unwrap();
    let ckpt = synthetic_kan_checkpoint("m", &[2, 3, 2], 5, 3, 0xD1CE);
    let src = dir.join("m.incoming.json");
    std::fs::write(&src, ckpt.to_value().to_string()).unwrap();
    registry.publish_file(&src, None, None).unwrap();
    registry
}

/// Spawn the registry-backed server with request tracing at
/// `cfg.observability.sample_every`.
fn spawn_traced(cfg: &AppConfig, dir: &Path) -> (Arc<ModelRegistry>, TcpServer) {
    let registry = publish_dense_model(dir, cfg);
    let target: Arc<dyn Dispatch> = registry.clone();
    let server =
        TcpServer::spawn_with_obs("127.0.0.1:0", target, tcp_limits(cfg), trace_hub(cfg))
            .unwrap();
    (registry, server)
}

// ---- end-to-end tracing over live TCP --------------------------------------

#[test]
fn traced_requests_stamp_every_stage_and_durations_partition_total() {
    let dir = tmp_dir("stages_partition");
    let mut cfg = common::test_config(&dir, "m");
    cfg.observability.sample_every = 1; // trace everything
    let (_registry, server) = spawn_traced(&cfg, &dir);
    let mut client = KanClient::connect(server.addr).unwrap();
    let n = 8;
    for i in 0..n {
        client.infer(&[0.1 * i as f32, -0.2]).unwrap();
    }

    // the span is finished *after* the response write, so the last one
    // can trail the client's view of its own request: poll, bounded
    let deadline = Instant::now() + Duration::from_secs(10);
    let spans: Vec<Value> = loop {
        let body = client.trace(Some(64)).unwrap();
        let spans: Vec<Value> = body
            .field("spans")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .filter(|s| s.get("model").and_then(|m| m.as_str()) == Some("m@1"))
            .cloned()
            .collect();
        if spans.len() >= n {
            break spans;
        }
        assert!(Instant::now() < deadline, "trace ring never saw {n} spans");
        std::thread::sleep(Duration::from_millis(10));
    };

    for span in &spans {
        assert_eq!(span.get("complete").and_then(|v| v.as_bool()), Some(true));
        let stages = span.field("stages_us").unwrap();
        let total = span.get("total_us").and_then(|v| v.as_i64()).unwrap();
        let mut sum = 0i64;
        for name in STAGE_NAMES {
            let d = stages
                .get(name)
                .and_then(|v| v.as_i64())
                .unwrap_or_else(|| panic!("stage '{name}' missing from {span}"));
            assert!(d >= 0, "stage '{name}' negative: {d}");
            sum += d;
        }
        // the five stages partition the request's server-side lifetime
        assert_eq!(sum, total, "stage durations must sum to total_us");
    }

    // the rollup surfaces in the metrics body as per-model p50/p99
    let body = client.metrics().unwrap();
    let report = body.field("models").unwrap().field("m@1").unwrap();
    let st = report.field("stages").unwrap();
    assert!(st.get("count").and_then(|v| v.as_i64()).unwrap() >= n as i64);
    for name in STAGE_NAMES {
        let s = st.field(name).unwrap();
        assert!(s.get("p50_us").and_then(|v| v.as_i64()).is_some());
        assert!(s.get("p99_us").and_then(|v| v.as_i64()).is_some());
    }
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sampling_one_in_n_traces_a_strict_subset() {
    let dir = tmp_dir("sampling_subset");
    let mut cfg = common::test_config(&dir, "m");
    cfg.observability.sample_every = 4;
    let (_registry, server) = spawn_traced(&cfg, &dir);
    let mut client = KanClient::connect(server.addr).unwrap();
    for _ in 0..16 {
        client.infer(&[0.3, 0.4]).unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let summary = client.trace(None).unwrap().field("summary").unwrap().clone();
        let sampled = summary.get("sampled_total").and_then(|v| v.as_i64()).unwrap();
        // 16 infers at 1-in-4: exactly 4 sampled (deterministic schedule)
        if sampled == 4 {
            break;
        }
        assert!(
            sampled < 16,
            "1-in-4 sampling must not trace every request (sampled {sampled})"
        );
        assert!(Instant::now() < deadline, "sampled_total never reached 4");
        std::thread::sleep(Duration::from_millis(10));
    }
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- bounded memory under sustained load -----------------------------------

#[test]
fn trace_ring_and_rollup_stay_bounded_after_100k_spans() {
    let hub = TraceHub::new(1, 256);
    for i in 0..100_000i64 {
        let span = hub.sample(i).expect("1-in-1 samples everything");
        for s in Stage::ALL {
            span.mark(s);
        }
        hub.finish(&span, "m");
    }
    assert_eq!(hub.ring_len(), 256, "ring must stay at its capacity");
    let summary = hub.summary_value();
    assert_eq!(
        summary.get("sampled_total").and_then(|v| v.as_i64()),
        Some(100_000)
    );
    assert_eq!(
        summary.get("completed_total").and_then(|v| v.as_i64()),
        Some(100_000)
    );
    // the rollup keeps counting past its window without growing
    let report = hub.stage_report("m").expect("rollup exists");
    assert_eq!(report.count, 100_000);
}

// ---- Prometheus plane agrees with the JSON plane ---------------------------

/// The value of the unique sample line starting with `prefix`.
fn prom_value(text: &str, prefix: &str) -> f64 {
    let line = text
        .lines()
        .find(|l| l.starts_with(prefix))
        .unwrap_or_else(|| panic!("no sample line starts with '{prefix}'"));
    line[prefix.len()..].trim().parse().unwrap()
}

#[test]
fn prom_scrape_validates_and_agrees_with_metrics_json() {
    let dir = tmp_dir("prom_agrees");
    let mut cfg = common::test_config(&dir, "m");
    cfg.observability.sample_every = 1;
    let (_registry, server) = spawn_traced(&cfg, &dir);
    let mut client = KanClient::connect(server.addr).unwrap();
    for i in 0..12 {
        client.infer(&[0.05 * i as f32, 0.5]).unwrap();
    }

    let body = client.metrics().unwrap();
    let text = client.metrics_prom().unwrap();
    kan_edge::obs::prom::validate(&text).expect("exposition text must parse");

    // wire and per-model infer counters only move on infer requests, so
    // the two scrapes (JSON first, text second) must agree on them
    let wire_v2 = body
        .field("wire")
        .unwrap()
        .field("v2_requests")
        .unwrap()
        .as_i64()
        .unwrap();
    assert_eq!(wire_v2, 12);
    assert_eq!(prom_value(&text, "kan_edge_wire_v2_requests "), wire_v2 as f64);

    let model_requests = body
        .field("models")
        .unwrap()
        .field("m@1")
        .unwrap()
        .field("requests")
        .unwrap()
        .as_i64()
        .unwrap();
    assert_eq!(
        prom_value(&text, "kan_edge_model_requests{model=\"m@1\"} "),
        model_requests as f64
    );

    // tracing counters cross both planes too
    assert_eq!(prom_value(&text, "kan_edge_trace_sample_every "), 1.0);
    assert!(prom_value(&text, "kan_edge_trace_sampled_total ") >= 12.0);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- engine profiling: bit parity + drift report ---------------------------

#[test]
fn engine_profiling_changes_no_served_bits_and_reports_drift() {
    let rows: Vec<Vec<f32>> = (0..16)
        .map(|i| vec![(i as f32 * 0.11).sin(), (i as f32 * 0.07).cos()])
        .collect();
    let mut outputs: Vec<Vec<Vec<f32>>> = Vec::new();
    for profiling in [false, true] {
        let dir = tmp_dir(&format!("profiling_{profiling}"));
        let mut cfg = common::test_config(&dir, "m");
        cfg.server.engine = true;
        cfg.observability.engine_profiling = profiling;
        let registry = publish_dense_model(&dir, &cfg);
        let mut logits = Vec::new();
        for row in &rows {
            let (id, out) = registry.infer(None, row.clone()).unwrap();
            assert_eq!(id, "m@1");
            logits.push(out);
        }
        let report = registry
            .metrics()
            .into_iter()
            .find(|(id, _)| id == "m@1")
            .map(|(_, r)| r)
            .unwrap();
        match report.engine_profile {
            None => assert!(!profiling, "profiling on must attach engine_profile"),
            Some(profile) => {
                assert!(profiling, "profiling off must not attach engine_profile");
                assert!(
                    profile.get("samples").and_then(|v| v.as_i64()).unwrap()
                        >= rows.len() as i64
                );
                let layers = profile.get("layers").and_then(|v| v.as_array()).unwrap();
                assert_eq!(layers.len(), 2, "one profile entry per layer");
                for l in layers {
                    let drift = l
                        .get("mapping_drift_rankcorr")
                        .and_then(|v| v.as_f64())
                        .expect("per-layer drift statistic");
                    assert!((-1.0..=1.0).contains(&drift), "rank corr in [-1,1]: {drift}");
                }
            }
        }
        outputs.push(logits);
        let _ = std::fs::remove_dir_all(&dir);
    }
    // profiling must not change one served bit
    for (a, b) in outputs[0].iter().zip(&outputs[1]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits(), "profiling changed a served bit");
        }
    }
}

// ---- scheduler gauges on the metrics plane ---------------------------------

#[test]
fn queue_gauges_appear_for_live_models() {
    let dir = tmp_dir("queue_gauges");
    let cfg = common::test_config(&dir, "m");
    let (registry, server) = spawn_traced(&cfg, &dir);
    let mut client = KanClient::connect(server.addr).unwrap();
    client.infer(&[0.2, 0.8]).unwrap();
    let report = registry
        .metrics()
        .into_iter()
        .find(|(id, _)| id == "m@1")
        .map(|(_, r)| r)
        .unwrap();
    // idle pipeline: gauges present and empty
    assert_eq!(report.queue_depth, Some(0));
    assert_eq!(report.max_client_backlog, Some(0));
    // and they ride the JSON plane
    let body = client.metrics().unwrap();
    let m = body.field("models").unwrap().field("m@1").unwrap();
    assert_eq!(m.get("queue_depth").and_then(|v| v.as_i64()), Some(0));
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
