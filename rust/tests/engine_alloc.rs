//! Zero-allocation steady-state assertion for the planned engine
//! (`docs/ENGINE.md`): once a scratch arena exists, per-sample forwards
//! must never touch the allocator.
//!
//! Lives in its own test binary so the counting global allocator cannot
//! observe allocations from unrelated tests running on sibling threads.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_forward_allocates_nothing() {
    let ckpt = kan_edge::kan::checkpoint::synthetic_kan_checkpoint(
        "alloc",
        &[17, 8, 14],
        5,
        3,
        0xA110C,
    );
    let model = kan_edge::kan::QuantKanModel::from_checkpoint(&ckpt);
    let engine = kan_edge::kan::KanEngine::compile(
        &model,
        kan_edge::kan::EngineOptions::default(),
    )
    .unwrap();
    let mut scratch = engine.new_scratch();
    let mut out = vec![0.0f64; engine.output_dim()];
    let mut lg = kan_edge::data::LoadGen::new(3, 17);
    let rows = lg.batch(128);

    // prime once (the contract covers steady state; the first call is
    // also alloc-free, but the measurement should not depend on that)
    engine.forward_into(&rows[0], &mut out, &mut scratch);

    let before = ALLOCS.load(Ordering::SeqCst);
    for row in &rows {
        engine.forward_into(row, &mut out, &mut scratch);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "engine steady state hit the allocator {} times over {} samples",
        after - before,
        rows.len()
    );
}

#[test]
fn steady_state_batch_major_forward_allocates_nothing() {
    // the batch-major path (SoA gather + counting-sort grouping) must
    // run entirely out of the preallocated scratch arenas, across block
    // boundaries and ragged tails, on both the fused and tiled plans
    let ckpt = kan_edge::kan::checkpoint::synthetic_kan_checkpoint(
        "alloc-batch",
        &[17, 8, 14],
        5,
        3,
        0xA110D,
    );
    let model = kan_edge::kan::QuantKanModel::from_checkpoint(&ckpt);
    let mut lg = kan_edge::data::LoadGen::new(4, 17);
    let batch = 100usize; // block of 64 + ragged tail of 36
    let flat: Vec<f32> =
        lg.batch(batch).into_iter().flatten().collect();
    for budget in [0usize, 1 << 22] {
        let engine = kan_edge::kan::KanEngine::compile(
            &model,
            kan_edge::kan::EngineOptions {
                fused_budget: budget,
                ..Default::default()
            },
        )
        .unwrap();
        // one scratch: the batch runs inline (scoped worker threads are
        // an explicit opt-in and allocate their stacks by design)
        let mut scratches = vec![engine.new_scratch()];
        let mut out = vec![0.0f64; batch * engine.output_dim()];

        // prime once, then the steady state must stay off the allocator
        engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..16 {
            engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
        }
        let after = ALLOCS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "batch-major steady state (budget {budget}) hit the allocator {} times",
            after - before,
        );
    }
}
