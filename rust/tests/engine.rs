//! Planned-engine parity suite (`docs/ENGINE.md`): the engine must agree
//! with the scalar golden reference (`forward_digital`) within the
//! documented LUT tolerance on *every* input code, be bit-identical
//! across its own execution variants (fused vs tiled, any worker
//! count, any tile order), and be argmax-identical on the artifact
//! dataset when the artifacts are present.

use std::sync::Arc;

use kan_edge::coordinator::{DigitalSession, ExecutionSession};
use kan_edge::data::LoadGen;
use kan_edge::kan::checkpoint::{synthetic_kan_checkpoint, Dataset};
use kan_edge::kan::{
    argmax, EngineOptions, EngineScratch, KanEngine, Manifest, QuantKanModel,
};
use kan_edge::mapping::MappingStrategy;

fn model(dims: &[usize], g: u32, k: u32, seed: u64) -> QuantKanModel {
    QuantKanModel::from_checkpoint(&synthetic_kan_checkpoint("t", dims, g, k, seed))
}

/// Engine vs reference differ only in float summation order: the engine
/// sums the spline path exactly in i64 and converts once, the reference
/// rounds per term. Bound that with a tight relative tolerance.
fn assert_close(got: &[f64], want: &[f64], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (x, y) in got.iter().zip(want) {
        let tol = 1e-9 * (1.0 + x.abs().max(y.abs()));
        assert!((x - y).abs() <= tol, "{ctx}: {x} vs {y}");
    }
}

#[test]
fn exhaustive_parity_over_every_input_code() {
    // single-input layers driven at every code 0..R, across several
    // (G, K) geometries, on both execution paths (fused and tiled)
    for &(g, k) in &[(5u32, 3u32), (8, 3), (16, 2), (64, 1), (7, 4)] {
        let m = model(&[1, 3], g, k, 0x5EED ^ ((g as u64) << 8) ^ k as u64);
        let spec = m.layers[0].spec;
        for budget in [0usize, 1 << 22] {
            let engine = KanEngine::compile(
                &m,
                EngineOptions { fused_budget: budget, ..Default::default() },
            )
            .unwrap();
            assert_eq!(engine.plan().layers[0].uses_fused(), budget > 0);
            for q in 0..spec.range() {
                let x = [spec.dequantize(q) as f32];
                // a code's abscissa quantizes back to that code
                assert_eq!(spec.quantize(x[0] as f64), q, "g={g} k={k} q={q}");
                let want = m.forward(&x);
                let got = engine.forward(&x);
                assert_close(&got, &want, &format!("g={g} k={k} q={q} budget={budget}"));
            }
        }
    }
}

#[test]
fn exhaustive_parity_over_all_code_pairs() {
    // two inputs, every (q0, q1) pair: cross-input accumulation order
    let m = model(&[2, 3], 5, 3, 0xD00D);
    let spec = m.layers[0].spec;
    let engine = KanEngine::compile(&m, EngineOptions::default()).unwrap();
    let mut s = engine.new_scratch();
    let mut out = vec![0.0f64; 3];
    for q0 in 0..spec.range() {
        for q1 in 0..spec.range() {
            let x = [spec.dequantize(q0) as f32, spec.dequantize(q1) as f32];
            engine.forward_into(&x, &mut out, &mut s);
            let want = m.forward(&x);
            assert_close(&out, &want, &format!("q0={q0} q1={q1}"));
        }
    }
}

#[test]
fn argmax_invariant_on_random_inputs() {
    let m = model(&[17, 8, 14], 5, 3, 0xACE);
    let engine = KanEngine::compile(&m, EngineOptions::default()).unwrap();
    let mut lg = LoadGen::new(42, 17);
    for _ in 0..500 {
        let x = lg.next_vec();
        assert_eq!(argmax(&m.forward(&x)), engine.predict(&x));
    }
}

#[test]
fn execution_variants_are_bit_identical() {
    // fused vs tiled vs tile order vs worker count: all compute the
    // same integer partial sums, so outputs must match to the bit
    let m = model(&[9, 6, 4], 8, 3, 0xF1F1);
    let base = KanEngine::compile(&m, EngineOptions::default()).unwrap();
    let variants = [
        EngineOptions { fused_budget: 0, ..Default::default() },
        EngineOptions { mapping: MappingStrategy::Uniform, ..Default::default() },
        EngineOptions {
            mapping: MappingStrategy::WorstCase,
            fused_budget: 0,
            ..Default::default()
        },
        // batch-major block geometries (and the row-major fallback via a
        // threshold no block reaches) must not change a single bit
        EngineOptions { block: 1, ..Default::default() },
        EngineOptions { block: 7, group_threshold: 3, ..Default::default() },
        EngineOptions { block: 256, fused_budget: 0, ..Default::default() },
        EngineOptions { group_threshold: usize::MAX, ..Default::default() },
    ];
    let mut lg = LoadGen::new(17, 9);
    let rows = lg.batch(40);
    for (vi, opts) in variants.iter().enumerate() {
        let other = KanEngine::compile(&m, *opts).unwrap();
        for row in &rows {
            let a = base.forward(row);
            let b = other.forward(row);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "variant {vi}");
            }
        }
    }
}

#[test]
fn batch_outputs_bit_identical_for_any_worker_count() {
    let m = model(&[17, 8, 14], 5, 3, 0xBEE);
    let engine = KanEngine::compile(&m, EngineOptions::default()).unwrap();
    let mut lg = LoadGen::new(5, 17);
    let batch = 37usize;
    let flat: Vec<f32> = lg.batch(batch).into_iter().flatten().collect();
    let mut base = vec![0.0f64; batch * 14];
    engine.forward_batch_with(&flat, batch, &mut base, &mut [engine.new_scratch()]);
    for workers in [2usize, 4, 7, 64] {
        let mut scratches: Vec<EngineScratch> =
            (0..workers).map(|_| engine.new_scratch()).collect();
        let mut out = vec![0.0f64; batch * 14];
        engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
        for (a, b) in out.iter().zip(&base) {
            assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
        }
    }
}

#[test]
fn batch_major_parity_across_batch_sizes_and_worker_counts() {
    // the issue-mandated grid: batch sizes around the block boundary
    // (1, 2, 63, 64, 65) plus a multi-block odd size (257), crossed with
    // worker counts; every cell must be bit-identical to the row-major
    // single-sample path AND within reference tolerance
    let m = model(&[17, 8, 14], 5, 3, 0xBA7C);
    for budget in [0usize, 1 << 22] {
        let engine = KanEngine::compile(
            &m,
            EngineOptions { fused_budget: budget, ..Default::default() },
        )
        .unwrap();
        let mut lg = LoadGen::new(31, 17);
        for &batch in &[1usize, 2, 63, 64, 65, 257] {
            let flat: Vec<f32> = lg.batch(batch).into_iter().flatten().collect();
            // golden: row-major forwards through one scratch
            let mut want = vec![0.0f64; batch * 14];
            let mut s = engine.new_scratch();
            for b in 0..batch {
                engine.forward_into(
                    &flat[b * 17..(b + 1) * 17],
                    &mut want[b * 14..(b + 1) * 14],
                    &mut s,
                );
            }
            for r in 0..batch {
                let reference = m.forward(&flat[r * 17..(r + 1) * 17]);
                assert_close(
                    &want[r * 14..(r + 1) * 14],
                    &reference,
                    &format!("batch={batch} row={r}"),
                );
            }
            for &workers in &[1usize, 2, 3, 8] {
                let mut scratches: Vec<EngineScratch> =
                    (0..workers).map(|_| engine.new_scratch()).collect();
                let mut out = vec![0.0f64; batch * 14];
                engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
                for (a, b) in out.iter().zip(&want) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "budget={budget} batch={batch} workers={workers}"
                    );
                }
            }
        }
    }
}

#[test]
fn batch_rows_straddling_interval_boundaries() {
    // rows pinned exactly on and around the knot-interval boundaries:
    // adjacent codes fall in different intervals, so the SoA grouping
    // walks many single-row groups and interval transitions in one block
    let m = model(&[2, 3], 5, 3, 0xB0DA);
    let spec = m.layers[0].spec;
    let levels = spec.levels_per_interval();
    let mut rows: Vec<[f32; 2]> = Vec::new();
    for j in 0..spec.g {
        // first and last code of interval j, paired against the interval
        // boundary seen from the second input
        let q_lo = j * levels;
        let q_hi = q_lo + levels - 1;
        rows.push([spec.dequantize(q_lo) as f32, spec.dequantize(q_hi) as f32]);
        rows.push([spec.dequantize(q_hi) as f32, spec.dequantize(q_lo) as f32]);
    }
    let batch = rows.len();
    let flat: Vec<f32> = rows.iter().flatten().copied().collect();
    for budget in [0usize, 1 << 22] {
        // a small block so the boundary rows also straddle block cuts
        let engine = KanEngine::compile(
            &m,
            EngineOptions { fused_budget: budget, block: 3, ..Default::default() },
        )
        .unwrap();
        let mut out = vec![0.0f64; batch * 3];
        engine.forward_batch_with(&flat, batch, &mut out, &mut [engine.new_scratch()]);
        for (r, row) in rows.iter().enumerate() {
            let want = m.forward(row);
            assert_close(&out[r * 3..(r + 1) * 3], &want, &format!("boundary row {r}"));
            let single = engine.forward(row);
            for (a, b) in out[r * 3..(r + 1) * 3].iter().zip(&single) {
                assert_eq!(a.to_bits(), b.to_bits(), "boundary row {r}");
            }
        }
    }
}

#[test]
fn degenerate_batch_where_every_row_maps_to_one_tile() {
    // all rows identical ⇒ every input column of every block collapses
    // to a single (input, interval) code group — the maximal-amortization
    // corner of the grouping path
    let m = model(&[3, 2], 5, 3, 0xDE6E);
    let engine = KanEngine::compile(
        &m,
        EngineOptions { fused_budget: 0, block: 64, ..Default::default() },
    )
    .unwrap();
    let batch = 300usize;
    let row = [0.2f32, -0.4, 0.9];
    let flat: Vec<f32> = row.iter().copied().cycle().take(batch * 3).collect();
    let mut out = vec![0.0f64; batch * 2];
    let mut scratches = vec![engine.new_scratch_profiled()];
    engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
    let want = m.forward(&row);
    let single = engine.forward(&row);
    for r in 0..batch {
        assert_close(&out[r * 2..(r + 1) * 2], &want, &format!("row {r}"));
        for (a, b) in out[r * 2..(r + 1) * 2].iter().zip(&single) {
            assert_eq!(a.to_bits(), b.to_bits(), "row {r}");
        }
    }
    // 300 rows cut into blocks of 64 ⇒ 5 blocks; with one distinct code
    // per column, layer 0 materializes exactly blocks × din products
    let p = scratches[0].profile().unwrap();
    assert_eq!(p.samples, batch as u64);
    assert_eq!(p.layers[0].tiles_touched, (batch * 3) as u64);
    assert_eq!(p.layers[0].tile_loads, 5 * 3);
}

#[test]
fn digital_backend_engine_matches_reference_path() {
    let m = Arc::new(model(&[17, 8, 14], 5, 3, 0xF00));
    let eng = DigitalSession::new(m.clone());
    assert!(eng.engine_enabled());
    let refp = DigitalSession::with_engine(m, false);
    assert!(!refp.engine_enabled());
    let mut lg = LoadGen::new(8, 17);
    let rows = lg.batch(20);
    let a = eng.infer_logits(rows.clone()).unwrap();
    let b = refp.infer_logits(rows).unwrap();
    for (ra, rb) in a.iter().zip(&b) {
        let fa: Vec<f64> = ra.iter().map(|&v| v as f64).collect();
        let fb: Vec<f64> = rb.iter().map(|&v| v as f64).collect();
        assert_eq!(argmax(&fa), argmax(&fb));
        for (x, y) in fa.iter().zip(&fb) {
            assert!((x - y).abs() <= 1e-5 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }
}

#[test]
fn argmax_and_accuracy_identical_on_artifact_dataset() {
    let dir = ["artifacts", "../artifacts"]
        .iter()
        .map(std::path::PathBuf::from)
        .find(|d| d.join("manifest.json").exists() && d.join("dataset.json").exists());
    let dir = match dir {
        Some(d) => d,
        None => {
            eprintln!("artifacts missing; skipping artifact parity check");
            return;
        }
    };
    let ds = Dataset::load(&dir).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let mut checked = 0usize;
    for (name, entry) in &manifest.models {
        if entry.kind != "kan" {
            continue;
        }
        let m = QuantKanModel::load(dir.join(&entry.weights)).unwrap();
        let engine = KanEngine::compile(&m, EngineOptions::default()).unwrap();
        for (row, _) in ds.test_rows() {
            assert_eq!(m.predict(row), engine.predict(row), "model {name}");
        }
        assert_eq!(m.accuracy(&ds), engine.accuracy(&ds), "model {name}");
        checked += 1;
    }
    assert!(checked > 0, "no kan models in the artifact manifest");
}
