//! Planned-engine parity suite (`docs/ENGINE.md`): the engine must agree
//! with the scalar golden reference (`forward_digital`) within the
//! documented LUT tolerance on *every* input code, be bit-identical
//! across its own execution variants (fused vs tiled, any worker
//! count, any tile order), and be argmax-identical on the artifact
//! dataset when the artifacts are present.

use std::sync::Arc;

use kan_edge::coordinator::{DigitalSession, ExecutionSession};
use kan_edge::data::LoadGen;
use kan_edge::kan::checkpoint::{synthetic_kan_checkpoint, Dataset};
use kan_edge::kan::{
    argmax, EngineOptions, EngineScratch, KanEngine, Manifest, QuantKanModel,
};
use kan_edge::mapping::MappingStrategy;

fn model(dims: &[usize], g: u32, k: u32, seed: u64) -> QuantKanModel {
    QuantKanModel::from_checkpoint(&synthetic_kan_checkpoint("t", dims, g, k, seed))
}

/// Engine vs reference differ only in float summation order: the engine
/// sums the spline path exactly in i64 and converts once, the reference
/// rounds per term. Bound that with a tight relative tolerance.
fn assert_close(got: &[f64], want: &[f64], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (x, y) in got.iter().zip(want) {
        let tol = 1e-9 * (1.0 + x.abs().max(y.abs()));
        assert!((x - y).abs() <= tol, "{ctx}: {x} vs {y}");
    }
}

#[test]
fn exhaustive_parity_over_every_input_code() {
    // single-input layers driven at every code 0..R, across several
    // (G, K) geometries, on both execution paths (fused and tiled)
    for &(g, k) in &[(5u32, 3u32), (8, 3), (16, 2), (64, 1), (7, 4)] {
        let m = model(&[1, 3], g, k, 0x5EED ^ ((g as u64) << 8) ^ k as u64);
        let spec = m.layers[0].spec;
        for budget in [0usize, 1 << 22] {
            let engine = KanEngine::compile(
                &m,
                EngineOptions { fused_budget: budget, ..Default::default() },
            )
            .unwrap();
            assert_eq!(engine.plan().layers[0].uses_fused(), budget > 0);
            for q in 0..spec.range() {
                let x = [spec.dequantize(q) as f32];
                // a code's abscissa quantizes back to that code
                assert_eq!(spec.quantize(x[0] as f64), q, "g={g} k={k} q={q}");
                let want = m.forward(&x);
                let got = engine.forward(&x);
                assert_close(&got, &want, &format!("g={g} k={k} q={q} budget={budget}"));
            }
        }
    }
}

#[test]
fn exhaustive_parity_over_all_code_pairs() {
    // two inputs, every (q0, q1) pair: cross-input accumulation order
    let m = model(&[2, 3], 5, 3, 0xD00D);
    let spec = m.layers[0].spec;
    let engine = KanEngine::compile(&m, EngineOptions::default()).unwrap();
    let mut s = engine.new_scratch();
    let mut out = vec![0.0f64; 3];
    for q0 in 0..spec.range() {
        for q1 in 0..spec.range() {
            let x = [spec.dequantize(q0) as f32, spec.dequantize(q1) as f32];
            engine.forward_into(&x, &mut out, &mut s);
            let want = m.forward(&x);
            assert_close(&out, &want, &format!("q0={q0} q1={q1}"));
        }
    }
}

#[test]
fn argmax_invariant_on_random_inputs() {
    let m = model(&[17, 8, 14], 5, 3, 0xACE);
    let engine = KanEngine::compile(&m, EngineOptions::default()).unwrap();
    let mut lg = LoadGen::new(42, 17);
    for _ in 0..500 {
        let x = lg.next_vec();
        assert_eq!(argmax(&m.forward(&x)), engine.predict(&x));
    }
}

#[test]
fn execution_variants_are_bit_identical() {
    // fused vs tiled vs tile order vs worker count: all compute the
    // same integer partial sums, so outputs must match to the bit
    let m = model(&[9, 6, 4], 8, 3, 0xF1F1);
    let base = KanEngine::compile(&m, EngineOptions::default()).unwrap();
    let variants = [
        EngineOptions { fused_budget: 0, ..Default::default() },
        EngineOptions { mapping: MappingStrategy::Uniform, ..Default::default() },
        EngineOptions {
            mapping: MappingStrategy::WorstCase,
            fused_budget: 0,
            workers: 1,
        },
    ];
    let mut lg = LoadGen::new(17, 9);
    let rows = lg.batch(40);
    for (vi, opts) in variants.iter().enumerate() {
        let other = KanEngine::compile(&m, *opts).unwrap();
        for row in &rows {
            let a = base.forward(row);
            let b = other.forward(row);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "variant {vi}");
            }
        }
    }
}

#[test]
fn batch_outputs_bit_identical_for_any_worker_count() {
    let m = model(&[17, 8, 14], 5, 3, 0xBEE);
    let engine = KanEngine::compile(&m, EngineOptions::default()).unwrap();
    let mut lg = LoadGen::new(5, 17);
    let batch = 37usize;
    let flat: Vec<f32> = lg.batch(batch).into_iter().flatten().collect();
    let mut base = vec![0.0f64; batch * 14];
    engine.forward_batch_with(&flat, batch, &mut base, &mut [engine.new_scratch()]);
    for workers in [2usize, 4, 7, 64] {
        let mut scratches: Vec<EngineScratch> =
            (0..workers).map(|_| engine.new_scratch()).collect();
        let mut out = vec![0.0f64; batch * 14];
        engine.forward_batch_with(&flat, batch, &mut out, &mut scratches);
        for (a, b) in out.iter().zip(&base) {
            assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
        }
    }
}

#[test]
fn digital_backend_engine_matches_reference_path() {
    let m = Arc::new(model(&[17, 8, 14], 5, 3, 0xF00));
    let eng = DigitalSession::new(m.clone());
    assert!(eng.engine_enabled());
    let refp = DigitalSession::with_engine(m, false);
    assert!(!refp.engine_enabled());
    let mut lg = LoadGen::new(8, 17);
    let rows = lg.batch(20);
    let a = eng.infer_logits(rows.clone()).unwrap();
    let b = refp.infer_logits(rows).unwrap();
    for (ra, rb) in a.iter().zip(&b) {
        let fa: Vec<f64> = ra.iter().map(|&v| v as f64).collect();
        let fb: Vec<f64> = rb.iter().map(|&v| v as f64).collect();
        assert_eq!(argmax(&fa), argmax(&fb));
        for (x, y) in fa.iter().zip(&fb) {
            assert!((x - y).abs() <= 1e-5 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }
}

#[test]
fn argmax_and_accuracy_identical_on_artifact_dataset() {
    let dir = ["artifacts", "../artifacts"]
        .iter()
        .map(std::path::PathBuf::from)
        .find(|d| d.join("manifest.json").exists() && d.join("dataset.json").exists());
    let dir = match dir {
        Some(d) => d,
        None => {
            eprintln!("artifacts missing; skipping artifact parity check");
            return;
        }
    };
    let ds = Dataset::load(&dir).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let mut checked = 0usize;
    for (name, entry) in &manifest.models {
        if entry.kind != "kan" {
            continue;
        }
        let m = QuantKanModel::load(dir.join(&entry.weights)).unwrap();
        let engine = KanEngine::compile(&m, EngineOptions::default()).unwrap();
        for (row, _) in ds.test_rows() {
            assert_eq!(m.predict(row), engine.predict(row), "model {name}");
        }
        assert_eq!(m.accuracy(&ds), engine.accuracy(&ds), "model {name}");
        checked += 1;
    }
    assert!(checked > 0, "no kan models in the artifact manifest");
}
