//! Cluster-plane integration tests: consistent-hash placement, the
//! front router's failover / hedging / on-demand replication, the
//! cluster metrics rollup, registry pinning under LRU pressure, and the
//! client's opt-in overload retry.
//!
//! Everything runs in-process: each "node" is a [`ModelRegistry`] over
//! its own temp artifacts dir behind a real [`TcpServer`] on an
//! ephemeral port, and the router is a [`ClusterRouter`] fronted by its
//! own `TcpServer` — the same wiring `kan-edge serve` / `kan-edge
//! route` produce, minus the processes.

#![allow(clippy::field_reassign_with_default)]

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

use kan_edge::client::{CallOptions, KanClient};
use kan_edge::cluster::{ClusterRouter, HashRing, NodeState, RouterOptions};
use kan_edge::coordinator::{
    ClientId, Dispatch, MetricsReport, ModelSummary, RouteSpec, RowOutput, TcpServer,
};
use kan_edge::error::{Error, Result};
use kan_edge::kan::checkpoint::synthetic_checkpoint_json;
use kan_edge::registry::{ModelManifest, ModelRegistry};
use kan_edge::util::json::Value;

mod common;
use common::{test_config, write_manifest_v2};

fn tmp(test: &str) -> PathBuf {
    common::tmp_dir("kan_edge_cluster_tests", test)
}

/// Artifacts dir with model "cm" (favors class 0) in the manifest.
fn dir_with_model(test: &str, node: usize) -> PathBuf {
    let dir = tmp(&format!("{test}_n{node}"));
    std::fs::write(dir.join("cm.weights.json"), synthetic_checkpoint_json("cm", 0)).unwrap();
    write_manifest_v2(&dir, &[("cm", "cm.weights.json", 1)]);
    dir
}

/// Artifacts dir with a valid but empty manifest (nothing published).
fn empty_dir(test: &str, node: usize) -> PathBuf {
    let dir = tmp(&format!("{test}_n{node}"));
    ModelManifest::empty().save(&dir).unwrap();
    dir
}

/// One in-process serving node: registry + wire endpoint.
struct Node {
    registry: Arc<ModelRegistry>,
    server: TcpServer,
}

fn spawn_node(dir: &Path) -> Node {
    let registry = ModelRegistry::open(&test_config(dir, "cm")).unwrap();
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
    Node { registry, server }
}

/// Router options for deterministic tests: no background heartbeat, no
/// hedging (tests that want hedging opt back in).
fn quiet_opts() -> RouterOptions {
    RouterOptions { heartbeat_ms: 0, hedge: false, ..RouterOptions::default() }
}

/// Front a router with its own wire endpoint and connect a client.
fn front(router: &Arc<ClusterRouter>) -> (TcpServer, KanClient) {
    let target: Arc<dyn Dispatch> = router.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
    let client = KanClient::connect(server.addr).unwrap();
    (server, client)
}

fn overlay_int(overlay: &Value, section: &str, key: &str) -> i64 {
    overlay
        .get(section)
        .and_then(|s| s.get(key))
        .and_then(|v| v.as_i64())
        .unwrap_or_else(|| panic!("no integer {section}.{key} in {overlay:?}"))
}

// ---- placement -----------------------------------------------------------

#[test]
fn node_leave_moves_only_the_departed_nodes_keys() {
    let full: Vec<String> = (0..5).map(|i| format!("node-{i}:77{i:02}")).collect();
    let before = HashRing::new(&full, 64);
    let after = HashRing::new(&full[..4], 64);
    let total = 2000;
    let mut moved = 0;
    for k in 0..total {
        let key = format!("model-{k}@1");
        let b = before.primary(&key).unwrap();
        let a = after.primary(&key).unwrap();
        if b == 4 {
            // orphaned keys respread among the survivors
            assert!(a < 4, "key {key} still maps to the departed node");
            moved += 1;
        } else {
            assert_eq!(a, b, "key {key} moved between surviving nodes {b} -> {a}");
        }
    }
    // the departed node owned about 1/5 of the space; generous slack
    assert!(moved > 0 && (moved as f64) < 0.45 * total as f64, "leave moved {moved}/{total}");
}

// ---- replication ---------------------------------------------------------

#[test]
fn routed_inference_replicates_on_demand() {
    let dirs: Vec<PathBuf> = (0..3).map(|i| empty_dir("replicate", i)).collect();
    let nodes: Vec<Node> = dirs.iter().map(|d| spawn_node(d)).collect();
    let addrs: Vec<String> = nodes.iter().map(|n| n.server.addr.to_string()).collect();
    let router = ClusterRouter::new(addrs, quiet_opts()).unwrap();

    // publish the model to exactly one node, *outside* its replica set:
    // every routed request lands on a node that does not have it yet
    let placement = router.placement("cm");
    assert_eq!(placement.len(), 2);
    let source = (0..3).find(|i| !placement.contains(i)).unwrap();
    let incoming = dirs[source].join("incoming.weights.json");
    std::fs::write(&incoming, synthetic_checkpoint_json("cm", 1)).unwrap();
    let (name, meta) = nodes[source].registry.publish_file(&incoming, Some("cm"), None).unwrap();
    assert_eq!((name.as_str(), meta.version), ("cm", 1));
    let dig = meta.digest.clone().unwrap();

    let (server, mut client) = front(&router);
    let inf = client.infer_model(Some("cm"), &[0.5, 0.5]).unwrap();
    assert_eq!(inf.model, "cm@1");
    assert!(inf.logits[1] > inf.logits[0], "replicated copy must serve v1 weights");

    // the primary now holds a digest-verified copy in its own store
    let primary = placement[0];
    assert!(nodes[primary].registry.model_names().contains(&"cm".to_string()));
    assert!(nodes[primary].registry.store().contains(&dig));
    // replication is on-demand, not broadcast: the other replica slot
    // stays empty until a request actually lands there
    assert!(!nodes[placement[1]].registry.model_names().contains(&"cm".to_string()));
    let overlay = router.metrics_overlay().unwrap();
    assert_eq!(overlay_int(&overlay, "cluster", "replications"), 1);
    assert_eq!(overlay_int(&overlay, "cluster", "replication_failures"), 0);

    // the copy persists: a second request serves locally, no new transfer
    let again = client.infer_model(Some("cm"), &[0.5, 0.5]).unwrap();
    assert_eq!(again.model, "cm@1");
    assert_eq!(again.logits, inf.logits);
    let overlay = router.metrics_overlay().unwrap();
    assert_eq!(overlay_int(&overlay, "cluster", "replications"), 1);

    server.shutdown();
    for n in &nodes {
        n.server.shutdown();
    }
}

#[test]
fn corrupted_push_is_rejected_and_store_untouched() {
    let dir = empty_dir("corrupt_push", 0);
    let registry = ModelRegistry::open(&test_config(&dir, "cm")).unwrap();
    let data = synthetic_checkpoint_json("x", 0).into_bytes();

    // digest mismatch: refused before anything touches the store
    let err = registry
        .push_artifact("x", Some(1), "fnv64:00000000000000ff", &data)
        .unwrap_err()
        .to_string();
    assert!(err.contains("digest mismatch"), "{err}");
    assert!(!registry.model_names().contains(&"x".to_string()));

    // correct digest publishes; an identical re-push is idempotent
    let dig = kan_edge::registry::digest_bytes(&data);
    assert_eq!(registry.push_artifact("x", Some(1), &dig, &data).unwrap(), "x@1");
    assert_eq!(registry.push_artifact("x", Some(1), &dig, &data).unwrap(), "x@1");
    assert!(registry.store().contains(&dig));
    let (id, logits) = registry.infer(Some("x"), vec![0.5, 0.5]).unwrap();
    assert_eq!(id, "x@1");
    assert_eq!(logits.len(), 2);
}

// ---- hedged retries ------------------------------------------------------

/// Wraps a node's dispatch with a settable artificial stall, so a test
/// can make one replica slow without touching the replica's outputs.
struct SlowDispatch {
    inner: Arc<dyn Dispatch>,
    delay_ms: AtomicU64,
}

impl SlowDispatch {
    fn stall(&self) {
        let ms = self.delay_ms.load(Ordering::Relaxed);
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

impl Dispatch for SlowDispatch {
    fn dispatch(
        &self,
        client: ClientId,
        route: &RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, RowOutput)> {
        self.stall();
        self.inner.dispatch(client, route, features)
    }

    fn dispatch_batch(
        &self,
        client: ClientId,
        route: &RouteSpec,
        rows: Vec<Vec<f32>>,
    ) -> Result<(String, Vec<RowOutput>)> {
        self.stall();
        self.inner.dispatch_batch(client, route, rows)
    }

    fn model_summaries(&self) -> Vec<ModelSummary> {
        self.inner.model_summaries()
    }

    fn metrics_reports(&self) -> Vec<(String, MetricsReport)> {
        self.inner.metrics_reports()
    }
}

#[test]
fn hedged_retry_beats_slow_primary_with_bit_identical_outputs() {
    let dirs: Vec<PathBuf> = (0..3).map(|i| dir_with_model("hedge", i)).collect();
    let mut servers = Vec::new();
    let mut delays = Vec::new();
    let mut addrs = Vec::new();
    for dir in &dirs {
        let registry = ModelRegistry::open(&test_config(dir, "cm")).unwrap();
        let slow = Arc::new(SlowDispatch { inner: registry, delay_ms: AtomicU64::new(0) });
        delays.push(slow.clone());
        let target: Arc<dyn Dispatch> = slow;
        let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
        addrs.push(server.addr.to_string());
        servers.push(server);
    }
    let opts = RouterOptions {
        heartbeat_ms: 0,
        hedge_min_ms: 1,
        hedge_max_ms: 5,
        ..RouterOptions::default()
    };
    let router = ClusterRouter::new(addrs, opts).unwrap();
    let placement = router.placement("cm");
    let (primary, secondary) = (placement[0], placement[1]);
    delays[primary].delay_ms.store(60, Ordering::Relaxed);

    let (server, mut client) = front(&router);
    let call = CallOptions { seed: Some(7), ..CallOptions::default() };
    let routed = client.infer_opts(Some("cm"), &[0.25, 0.75], &call).unwrap();
    assert_eq!(routed.model, "cm@1");

    let overlay = router.metrics_overlay().unwrap();
    assert!(overlay_int(&overlay, "cluster", "hedges") >= 1, "hedge never fired: {overlay:?}");
    assert!(overlay_int(&overlay, "cluster", "hedge_wins") >= 1, "hedge never won: {overlay:?}");

    // idempotence: the fast winner and the slow loser are bit-identical,
    // so it cannot matter which answer the caller got
    delays[primary].delay_ms.store(0, Ordering::Relaxed);
    for node in [primary, secondary] {
        let mut direct = KanClient::connect(servers[node].addr).unwrap();
        let d = direct.infer_opts(Some("cm"), &[0.25, 0.75], &call).unwrap();
        assert_eq!(d.logits, routed.logits, "node {node} diverged from the routed answer");
        assert_eq!(d.class, routed.class);
    }

    server.shutdown();
    for s in &servers {
        s.shutdown();
    }
}

// ---- failover ------------------------------------------------------------

#[test]
fn killed_node_fails_over_and_cluster_keeps_serving() {
    let dirs: Vec<PathBuf> = (0..3).map(|i| dir_with_model("failover", i)).collect();
    let nodes: Vec<Node> = dirs.iter().map(|d| spawn_node(d)).collect();
    let addrs: Vec<String> = nodes.iter().map(|n| n.server.addr.to_string()).collect();
    let router = ClusterRouter::new(addrs, quiet_opts()).unwrap();
    let placement = router.placement("cm");
    let (primary, secondary) = (placement[0], placement[1]);

    // kill the primary before any traffic
    nodes[primary].server.shutdown();

    let (server, mut client) = front(&router);
    let call = CallOptions { seed: Some(9), ..CallOptions::default() };
    let mut answers = Vec::new();
    for _ in 0..3 {
        let inf = client.infer_opts(Some("cm"), &[0.5, 0.5], &call).unwrap();
        assert_eq!(inf.model, "cm@1");
        answers.push(inf.logits);
    }
    // the survivor serves bit-identical outputs for the same (row, seed)
    assert_eq!(answers[0], answers[1]);
    assert_eq!(answers[0], answers[2]);
    let mut direct = KanClient::connect(nodes[secondary].server.addr).unwrap();
    let d = direct.infer_opts(Some("cm"), &[0.5, 0.5], &call).unwrap();
    assert_eq!(d.logits, answers[0]);

    // fail_after=2 data-path failures demoted the dead node; later
    // requests skip it at selection time instead of failing over
    assert_eq!(router.membership().state(primary), NodeState::Down);
    let overlay = router.metrics_overlay().unwrap();
    assert_eq!(overlay_int(&overlay, "cluster", "nodes_up"), 2);
    assert_eq!(overlay_int(&overlay, "cluster", "failovers"), 2);
    assert_eq!(overlay_int(&overlay, "cluster", "forwards"), 3);

    server.shutdown();
    nodes[secondary].server.shutdown();
    for (i, n) in nodes.iter().enumerate() {
        if i != primary && i != secondary {
            n.server.shutdown();
        }
    }
}

#[test]
fn draining_node_receives_no_traffic() {
    let dirs: Vec<PathBuf> = (0..2).map(|i| dir_with_model("draining", i)).collect();
    let nodes: Vec<Node> = dirs.iter().map(|d| spawn_node(d)).collect();
    let addrs: Vec<String> = nodes.iter().map(|n| n.server.addr.to_string()).collect();
    let router = ClusterRouter::new(addrs, quiet_opts()).unwrap();
    let placement = router.placement("cm");

    router.membership().set_draining(placement[0], true);
    let (server, mut client) = front(&router);
    for _ in 0..3 {
        client.infer_model(Some("cm"), &[0.5, 0.5]).unwrap();
    }
    assert_eq!(nodes[placement[0]].registry.aggregate_metrics().requests, 0);
    assert_eq!(nodes[placement[1]].registry.aggregate_metrics().requests, 3);

    // un-draining restores the normal preference order
    router.membership().set_draining(placement[0], false);
    client.infer_model(Some("cm"), &[0.5, 0.5]).unwrap();
    assert_eq!(nodes[placement[0]].registry.aggregate_metrics().requests, 1);

    server.shutdown();
    for n in &nodes {
        n.server.shutdown();
    }
}

// ---- metrics rollup ------------------------------------------------------

#[test]
fn router_metrics_rollup_sums_node_counters_exactly() {
    let dirs: Vec<PathBuf> = (0..2).map(|i| dir_with_model("rollup", i)).collect();
    let nodes: Vec<Node> = dirs.iter().map(|d| spawn_node(d)).collect();
    let addrs: Vec<String> = nodes.iter().map(|n| n.server.addr.to_string()).collect();

    // drive known per-node request counts *directly*, bypassing the router
    for (node, count) in [(0usize, 3usize), (1, 2)] {
        let mut c = KanClient::connect(nodes[node].server.addr).unwrap();
        for _ in 0..count {
            c.infer_model(Some("cm"), &[0.5, 0.5]).unwrap();
        }
    }

    let router = ClusterRouter::new(addrs.clone(), quiet_opts()).unwrap();
    let overlay = router.metrics_overlay().unwrap();
    // per-model integer counters sum exactly across nodes
    let cm = overlay.get("models").and_then(|m| m.get("cm@1")).unwrap();
    assert_eq!(cm.get("requests").unwrap().as_i64().unwrap(), 5);
    // per-node entries keyed by label (address until an id is reported)
    let n0 = overlay.get("nodes").and_then(|n| n.get(&addrs[0])).unwrap();
    assert_eq!(n0.get("requests").unwrap().as_i64().unwrap(), 3);
    assert_eq!(n0.get("up").unwrap().as_i64().unwrap(), 1);
    let n1 = overlay.get("nodes").and_then(|n| n.get(&addrs[1])).unwrap();
    assert_eq!(n1.get("requests").unwrap().as_i64().unwrap(), 2);

    // the same rollup crosses the wire: the router's own endpoint merges
    // it into `metrics` and renders `node`-labeled Prometheus series
    let (server, mut client) = front(&router);
    let body = client.metrics().unwrap();
    let via_wire = body.get("models").and_then(|m| m.get("cm@1")).unwrap();
    assert_eq!(via_wire.get("requests").unwrap().as_i64().unwrap(), 5);
    let prom = client.metrics_prom().unwrap();
    assert!(prom.contains("kan_edge_cluster_forwards"), "{prom}");
    let series = format!("kan_edge_node_requests{{node=\"{}\"}} 3", addrs[0]);
    assert!(prom.contains(&series), "missing {series} in:\n{prom}");

    server.shutdown();
    for n in &nodes {
        n.server.shutdown();
    }
}

// ---- registry pinning ----------------------------------------------------

#[test]
fn pinned_variant_survives_lru_pressure() {
    let dir = tmp("pinning");
    let variants = [("a", 0), ("b", 1), ("c", 0), ("d", 1)];
    for (name, favor) in variants {
        let file = format!("{name}.weights.json");
        std::fs::write(dir.join(&file), synthetic_checkpoint_json(name, favor)).unwrap();
    }
    write_manifest_v2(
        &dir,
        &[
            ("a", "a.weights.json", 1),
            ("b", "b.weights.json", 1),
            ("c", "c.weights.json", 1),
            ("d", "d.weights.json", 1),
        ],
    );
    let mut cfg = test_config(&dir, "a");
    cfg.registry.max_loaded = 2;
    let registry = ModelRegistry::open(&cfg).unwrap();

    registry.pin("a").unwrap();
    assert!(registry.is_pinned("a"));
    // fill the LRU well past capacity: every admission after the second
    // must evict, and "a" would be the LRU victim each time
    for (name, _) in variants {
        registry.infer(Some(name), vec![0.5, 0.5]).unwrap();
    }
    let live: Vec<(String, bool)> =
        registry.models().iter().map(|m| (m.name.clone(), m.live)).collect();
    let expect = [("a", true), ("b", false), ("c", false), ("d", true)];
    let expect: Vec<(String, bool)> =
        expect.iter().map(|(n, l)| (n.to_string(), *l)).collect();
    assert_eq!(live, expect, "pinned 'a' must survive; eviction falls on the LRU unpinned");
    let (id, logits) = registry.infer(Some("a"), vec![0.5, 0.5]).unwrap();
    assert_eq!(id, "a@1");
    assert!(logits[0] > logits[1]);

    // pinning an unknown model is a clear error
    let err = registry.pin("zzz").unwrap_err().to_string();
    assert!(err.contains("zzz"), "{err}");
    // version-qualified pins must match the manifest's current version
    let err = registry.pin("a@9").unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    // unpinned, "a" ages out normally again
    assert!(registry.unpin("a"));
    assert!(!registry.is_pinned("a"));
    registry.infer(Some("c"), vec![0.5, 0.5]).unwrap(); // evicts "d" (LRU)
    registry.infer(Some("b"), vec![0.5, 0.5]).unwrap(); // evicts "a"
    let live_a = registry.models().iter().find(|m| m.name == "a").unwrap().live;
    assert!(!live_a, "unpinned variant must be evictable again");
}

// ---- client overload retry -----------------------------------------------

/// Rejects the next `remaining` dispatches with a structured overload
/// (retry hint attached), then forwards to the real registry.
struct FlakyOverload {
    inner: Arc<ModelRegistry>,
    remaining: AtomicU32,
}

impl Dispatch for FlakyOverload {
    fn dispatch(
        &self,
        client: ClientId,
        route: &RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, RowOutput)> {
        let rejected = self
            .remaining
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
            .is_ok();
        if rejected {
            return Err(Error::Overloaded {
                message: "induced overload".into(),
                retry_after_ms: 5,
            });
        }
        self.inner.dispatch(client, route, features)
    }

    fn model_summaries(&self) -> Vec<ModelSummary> {
        self.inner.model_summaries()
    }
}

#[test]
fn client_retries_overloaded_once_when_asked() {
    let dir = dir_with_model("retry_overloaded", 0);
    let registry = ModelRegistry::open(&test_config(&dir, "cm")).unwrap();
    let flaky = Arc::new(FlakyOverload { inner: registry, remaining: AtomicU32::new(1) });
    let target: Arc<dyn Dispatch> = flaky.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
    let mut client = KanClient::connect(server.addr).unwrap();

    // default options surface the structured rejection, hint intact
    let err = client.infer_opts(Some("cm"), &[0.5, 0.5], &CallOptions::default()).unwrap_err();
    match err {
        Error::Overloaded { retry_after_ms, .. } => assert_eq!(retry_after_ms, 5),
        other => panic!("expected overloaded, got: {other}"),
    }

    // opted-in retry absorbs exactly one rejection
    flaky.remaining.store(1, Ordering::SeqCst);
    let call = CallOptions { retry_overloaded: true, ..CallOptions::default() };
    let inf = client.infer_opts(Some("cm"), &[0.5, 0.5], &call).unwrap();
    assert_eq!(inf.model, "cm@1");

    // two consecutive rejections still fail: the retry is single-shot
    flaky.remaining.store(2, Ordering::SeqCst);
    let err = client.infer_opts(Some("cm"), &[0.5, 0.5], &call).unwrap_err();
    assert!(matches!(err, Error::Overloaded { .. }), "{err}");
    server.shutdown();
}
