//! Backend API v2 integration tests: per-request backend selection over
//! the v2 wire, ACIM per-request-seed reproducibility (any worker
//! count, any interleaving), structured unknown-backend errors, served
//! capability descriptors on the control plane, ACIM shadow serving
//! with divergence counters, and the shadow no-added-latency contract.
//! Fully offline (synthetic KAN checkpoints published into temp
//! registries).

#![allow(clippy::field_reassign_with_default)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kan_edge::client::{CallOptions, KanClient};
use kan_edge::config::AppConfig;
use kan_edge::coordinator::protocol::{read_frame, write_frame, FrameRead, MAGIC};
use kan_edge::coordinator::{BackendKind, Dispatch, TcpServer};
use kan_edge::kan::checkpoint::synthetic_kan_checkpoint;
use kan_edge::registry::{ModelManifest, ModelRegistry};
use kan_edge::util::json::Value;

mod common;

fn tmp_dir(test: &str) -> PathBuf {
    common::tmp_dir("kan_edge_backend_v2_tests", test)
}

/// Publish a synthetic KAN with real (nonzero) spline mass as model "m"
/// into a fresh registry dir. The [2,2] routing fixture has all-zero
/// spline coefficients, which an analog crossbar reproduces exactly —
/// useless for divergence tests — so these suites use a dense one.
fn publish_dense_model(dir: &Path, cfg: &AppConfig) -> Arc<ModelRegistry> {
    ModelManifest::empty().save(dir).unwrap();
    let registry = ModelRegistry::open(cfg).unwrap();
    let ckpt = synthetic_kan_checkpoint("m", &[2, 3, 2], 5, 3, 0xD1CE);
    let src = dir.join("m.incoming.json");
    std::fs::write(&src, ckpt.to_value().to_string()).unwrap();
    registry.publish_file(&src, None, None).unwrap();
    registry
}

fn base_config(dir: &Path) -> AppConfig {
    let mut cfg = common::test_config(dir, "m");
    // stochastic analog path with visible noise, so seed semantics and
    // divergence are observable (not just reproducibly zero)
    cfg.hardware.acim.array.sigma_read = 0.5;
    cfg
}

fn spawn(cfg: &AppConfig, dir: &Path) -> (Arc<ModelRegistry>, TcpServer) {
    let registry = publish_dense_model(dir, cfg);
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
    (registry, server)
}

/// The `shadow` section of model `id`'s served metrics, if present.
fn shadow_section(client: &mut KanClient, id: &str) -> Option<Value> {
    let body = client.metrics().unwrap();
    body.field("models")
        .unwrap()
        .get(id)
        .and_then(|m| m.get("shadow"))
        .cloned()
}

/// Poll until every sampled shadow row is accounted for (mirrored,
/// dropped, or errored), bounded.
fn wait_shadow_drained(client: &mut KanClient, id: &str) -> Value {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        if let Some(s) = shadow_section(client, id) {
            let count = |k: &str| s.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
            if count("sampled") > 0
                && count("mirrored") + count("dropped") + count("errors")
                    >= count("sampled")
            {
                return s;
            }
        }
        assert!(Instant::now() < deadline, "shadow mirror never drained");
        std::thread::sleep(Duration::from_millis(20));
    }
}

// ---- per-request backend selection + seed reproducibility ------------------

#[test]
fn acim_fixed_row_and_seed_is_bit_identical_across_worker_counts() {
    let row = vec![0.3f32, -0.6];
    let opts = CallOptions {
        backend: Some(BackendKind::Acim),
        seed: Some(0xABCD),
        trials: 1,
        ..CallOptions::default()
    };
    let mut outputs = Vec::new();
    for workers in [1usize, 4] {
        let dir = tmp_dir(&format!("seed_workers_{workers}"));
        let mut cfg = base_config(&dir);
        cfg.server.workers = workers;
        let (_registry, server) = spawn(&cfg, &dir);
        let mut client = KanClient::connect(server.addr).unwrap();
        // submit the same (row, seed) repeatedly, interleaved with other
        // traffic, from several concurrent connections: every answer
        // must be bit-identical
        let mut logits = Vec::new();
        for i in 0..6 {
            // interleaving traffic with different seeds and backends
            client.infer(&[i as f32 * 0.1, 0.2]).unwrap();
            let out = client.infer_opts(None, &row, &opts).unwrap();
            assert_eq!(out.model, "m@1");
            logits.push(out.logits);
        }
        let addr = server.addr;
        let mut handles = Vec::new();
        for _ in 0..4 {
            let row = row.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = KanClient::connect(addr).unwrap();
                c.infer_opts(None, &row, &opts).unwrap().logits
            }));
        }
        for h in handles {
            logits.push(h.join().unwrap());
        }
        for l in &logits {
            assert_eq!(
                l.clone(),
                logits[0].clone(),
                "non-deterministic ACIM output under workers={workers}"
            );
        }
        outputs.push(logits[0].clone());
        server.shutdown();
    }
    // identical across server instances with different worker pools
    assert_eq!(outputs[0], outputs[1]);
}

#[test]
fn one_connection_interleaves_digital_and_acim_against_one_model() {
    let dir = tmp_dir("interleave");
    let cfg = base_config(&dir);
    let (_registry, server) = spawn(&cfg, &dir);
    let mut client = KanClient::connect(server.addr).unwrap();

    let row = vec![0.25f32, 0.75];
    let digital = client.infer(&row).unwrap();
    let acim = client
        .infer_opts(
            None,
            &row,
            &CallOptions {
                backend: Some(BackendKind::Acim),
                seed: Some(1),
                trials: 1,
                ..CallOptions::default()
            },
        )
        .unwrap();
    // same model id serves both; the analog path visibly diverges from
    // the exact digital one (sigma_read is large here)
    assert_eq!(digital.model, "m@1");
    assert_eq!(acim.model, "m@1");
    assert_ne!(digital.logits, acim.logits);
    // interleave freely: digital answers stay bit-stable, acim answers
    // reproduce per seed
    let d2 = client.infer(&row).unwrap();
    assert_eq!(d2.logits, digital.logits);
    let a2 = client
        .infer_opts(
            None,
            &row,
            &CallOptions {
                backend: Some(BackendKind::Acim),
                seed: Some(1),
                trials: 1,
                ..CallOptions::default()
            },
        )
        .unwrap();
    assert_eq!(a2.logits, acim.logits);
    // a different seed draws different noise
    let a3 = client
        .infer_opts(
            None,
            &row,
            &CallOptions {
                backend: Some(BackendKind::Acim),
                seed: Some(2),
                trials: 1,
                ..CallOptions::default()
            },
        )
        .unwrap();
    assert_ne!(a3.logits, acim.logits);

    // explicit primary-kind selection is also valid
    let d3 = client
        .infer_opts(
            None,
            &row,
            &CallOptions {
                backend: Some(BackendKind::Digital),
                seed: None,
                trials: 1,
                ..CallOptions::default()
            },
        )
        .unwrap();
    assert_eq!(d3.logits, digital.logits);

    // seeded batch submit on the acim backend reproduces row by row
    let rows: Vec<Vec<f32>> = (0..5).map(|i| vec![0.1 * i as f32, -0.2]).collect();
    let opts = CallOptions {
        backend: Some(BackendKind::Acim),
        seed: Some(9),
        trials: 1,
        ..CallOptions::default()
    };
    let (_, b1) = client.infer_batch_opts(None, rows.clone(), &opts).unwrap();
    let (_, b2) = client.infer_batch_opts(None, rows, &opts).unwrap();
    assert_eq!(b1, b2);
    server.shutdown();
}

#[test]
fn acim_trials_serve_uncertainty_estimates() {
    let dir = tmp_dir("trials");
    let cfg = base_config(&dir);
    let (_registry, server) = spawn(&cfg, &dir);
    let mut client = KanClient::connect(server.addr).unwrap();

    let row = vec![0.4f32, -0.1];
    let opts = CallOptions {
        backend: Some(BackendKind::Acim),
        seed: Some(77),
        trials: 16,
        ..CallOptions::default()
    };
    let out = client.infer_opts(None, &row, &opts).unwrap();
    let std = out.std.as_ref().expect("trials > 1 must serve a trial spread");
    assert_eq!(std.len(), out.logits.len());
    // real noise → nonzero spread somewhere
    assert!(std.iter().any(|&s| s > 0.0), "{std:?}");
    // repeated trials are reproducible too
    let again = client.infer_opts(None, &row, &opts).unwrap();
    assert_eq!(out.logits, again.logits);
    assert_eq!(out.std, again.std);
    // single-trial responses carry no std field
    let single = client
        .infer_opts(
            None,
            &row,
            &CallOptions {
                backend: Some(BackendKind::Acim),
                seed: Some(77),
                trials: 1,
                ..CallOptions::default()
            },
        )
        .unwrap();
    assert!(single.std.is_none());
    // out-of-range trials are a typed wire error
    let err = client
        .infer_opts(
            None,
            &row,
            &CallOptions {
                backend: Some(BackendKind::Acim),
                seed: None,
                trials: 1000,
                ..CallOptions::default()
            },
        )
        .unwrap_err();
    assert!(err.to_string().contains("trials"), "{err}");
    server.shutdown();
}

// ---- routing errors + control plane ----------------------------------------

#[test]
fn unknown_and_unserveable_backends_are_structured_errors() {
    let dir = tmp_dir("bad_backend");
    let cfg = base_config(&dir);
    let (_registry, server) = spawn(&cfg, &dir);

    // unknown backend name: typed bad_request at the wire boundary
    let mut conn = TcpStream::connect(server.addr).unwrap();
    conn.write_all(&MAGIC).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    write_frame(
        &mut conn,
        br#"{"id": 1, "op": "infer", "backend": "gpu", "features": [0.1, 0.2]}"#,
    )
    .unwrap();
    let v = match read_frame(&mut reader, 1 << 20).unwrap() {
        FrameRead::Frame(p) => Value::parse(std::str::from_utf8(&p).unwrap()).unwrap(),
        other => panic!("{other:?}"),
    };
    assert_eq!(v.get("code").unwrap().as_str().unwrap(), "bad_request");
    assert!(v
        .get("error")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("unknown backend 'gpu'"));

    // a known kind the artifact cannot back: structured not_found
    let mut client = KanClient::connect(server.addr).unwrap();
    let err = client
        .infer_opts(
            None,
            &[0.1, 0.2],
            &CallOptions {
                backend: Some(BackendKind::Mlp),
                seed: None,
                trials: 1,
                ..CallOptions::default()
            },
        )
        .unwrap_err();
    assert!(err.to_string().contains("not_found"), "{err}");

    // v1 gets a clean refusal for the new fields over a real socket
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(b"{\"features\": [0.1, 0.2], \"backend\": \"acim\"}\n")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Value::parse(line.trim()).unwrap();
    assert_eq!(v.get("code").unwrap().as_str().unwrap(), "unsupported");
    assert!(v.get("error").unwrap().as_str().unwrap().contains("protocol v2"));
    server.shutdown();
}

#[test]
fn control_plane_reports_backend_capabilities_and_shadow_status() {
    let dir = tmp_dir("capabilities");
    let mut cfg = base_config(&dir);
    cfg.server.shadow.backend = Some(BackendKind::Acim);
    cfg.server.shadow.fraction = 0.25;
    let (_registry, server) = spawn(&cfg, &dir);
    let mut client = KanClient::connect(server.addr).unwrap();

    // not live yet: no compiled session to describe
    let info = client.model_info("m").unwrap();
    assert!(info.backend.is_none());

    client.infer(&[0.1, 0.2]).unwrap(); // load the pipeline
    let info = client.model_info("m").unwrap();
    let be = info.backend.expect("live model must report its backend spec");
    assert_eq!(be.kind, "digital");
    assert!(be.deterministic);
    assert!(be.reference_exact);
    assert_eq!(be.input_dim, Some(2));
    assert_eq!(be.output_dim, 2);
    let (shadow_kind, fraction) = be.shadow.expect("shadow status must be reported");
    assert_eq!(shadow_kind, "acim");
    assert!((fraction - 0.25).abs() < 1e-12);
    server.shutdown();
}

// ---- shadow serving ---------------------------------------------------------

#[test]
fn shadow_mirror_records_divergence_on_live_traffic() {
    let dir = tmp_dir("shadow_divergence");
    let mut cfg = base_config(&dir);
    cfg.server.shadow.backend = Some(BackendKind::Acim);
    cfg.server.shadow.fraction = 1.0;
    cfg.server.shadow.queue = 4096;
    // crank read noise: mirrored analog outputs must visibly flip
    cfg.hardware.acim.array.sigma_read = 2.0;
    let (_registry, server) = spawn(&cfg, &dir);
    let mut client = KanClient::connect(server.addr).unwrap();

    let mut lg = kan_edge::data::LoadGen::new(0x5EED, 2);
    for _ in 0..20 {
        client.infer(&lg.next_vec()).unwrap();
    }
    client.infer_batch(None, lg.batch(40)).unwrap();

    let s = wait_shadow_drained(&mut client, "m@1");
    let count = |k: &str| s.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
    assert_eq!(count("sampled"), 60, "fraction 1.0 must sample every row");
    assert!(count("mirrored") > 0, "{s}");
    assert_eq!(count("errors"), 0, "{s}");
    assert!(
        count("argmax_flips") > 0,
        "heavy read noise must flip some argmaxes: {s}"
    );
    assert!(s.get("logit_mae_mean").unwrap().as_f64().unwrap() > 0.0);
    // per-layer partial-sum error quantiles, one entry per layer
    let layers = s.get("layer_err").unwrap().as_array().unwrap();
    assert_eq!(layers.len(), 2);
    for l in layers {
        let p50 = l.get("p50").unwrap().as_f64().unwrap();
        let p99 = l.get("p99").unwrap().as_f64().unwrap();
        assert!(p99 >= p50 && p50 >= 0.0);
    }

    // mirrored traffic does not error or reject the primary path
    let body = client.metrics().unwrap();
    let model = body.field("models").unwrap().get("m@1").unwrap().clone();
    assert_eq!(model.get("errors").unwrap().as_i64().unwrap(), 0);
    assert_eq!(model.get("requests").unwrap().as_i64().unwrap(), 60);
    server.shutdown();
}

#[test]
fn shadow_overflow_drops_instead_of_delaying_primary_responses() {
    let dir = tmp_dir("shadow_no_latency");
    let mut cfg = base_config(&dir);
    cfg.server.shadow.backend = Some(BackendKind::Acim);
    cfg.server.shadow.fraction = 1.0;
    cfg.server.shadow.queue = 2; // force overflow under any burst
    let (_registry, server) = spawn(&cfg, &dir);
    let mut client = KanClient::connect(server.addr).unwrap();
    client.infer(&[0.1, 0.2]).unwrap(); // build both pipelines up front

    let mut lg = kan_edge::data::LoadGen::new(0xF10D, 2);
    // a burst far larger than the mirror queue: every primary response
    // must come back promptly and successfully even though the mirror
    // cannot keep up — overflow is counted as drops, never as waiting
    let (_, results) = client.infer_batch(None, lg.batch(300)).unwrap();
    assert_eq!(results.len(), 300);

    let s = wait_shadow_drained(&mut client, "m@1");
    let count = |k: &str| s.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
    assert_eq!(count("sampled"), 301);
    assert!(
        count("dropped") > 0,
        "queue of 2 under a 300-row burst must have dropped: {s}"
    );
    assert_eq!(count("mirrored") + count("dropped") + count("errors"), 301);
    server.shutdown();
}

// ---- calibrate-once caching -------------------------------------------------

#[test]
fn acim_occupancy_is_cached_across_rebuilds() {
    let dir = tmp_dir("occupancy_cache");
    let cfg = base_config(&dir);
    let registry = publish_dense_model(&dir, &cfg);
    assert_eq!(registry.factory().occupancy_cache_len(), 0);

    // first ACIM build calibrates once
    let row = vec![0.2f32, 0.4];
    registry.ensure_loaded("m").unwrap();
    let (_, out1) = registry.infer(Some("m"), row.clone()).unwrap();
    assert_eq!(out1.len(), 2);
    let mut raw = kan_edge::coordinator::RouteSpec::to_model(Some("m"));
    raw.backend = Some(BackendKind::Acim);
    raw.opts.seed = Some(3);
    let (_, a1) = registry
        .infer_route_from(kan_edge::coordinator::ClientId::fresh(), &raw, row.clone())
        .unwrap();
    assert_eq!(registry.factory().occupancy_cache_len(), 1);

    // hot-swap rebuild (same weights): the ACIM pipeline is rebuilt but
    // the calibration occupancy is a cache hit, and seeded outputs are
    // unchanged
    registry.reload_model("m").unwrap();
    let (_, a2) = registry
        .infer_route_from(kan_edge::coordinator::ClientId::fresh(), &raw, row)
        .unwrap();
    assert_eq!(registry.factory().occupancy_cache_len(), 1);
    assert_eq!(a1.logits, a2.logits);
}
