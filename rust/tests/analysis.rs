//! Analyzer behavior tests: each fixture under `fixtures/lint/` is
//! planted into a throwaway mini-tree at the path its rule polices,
//! `run_lint` runs over that tree, and the expected rule (and only the
//! expected rule) must fire. The final test is the self-check: the
//! shipped tree must be clean — zero findings, zero reason-less
//! suppressions.

use kan_edge::analysis::{run_lint, Finding, LintOutcome};
use std::path::{Path, PathBuf};

/// Build a disposable repo-shaped tree containing `files` (repo-relative
/// path → contents) and lint it.
fn lint_tree(tag: &str, files: &[(&str, &str)]) -> LintOutcome {
    let root = std::env::temp_dir()
        .join(format!("kan_edge_lint_fixture_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    for (rel, content) in files {
        let p = root.join(rel);
        std::fs::create_dir_all(p.parent().expect("fixture path has a parent"))
            .expect("mkdir fixture tree");
        std::fs::write(&p, content).expect("write fixture");
    }
    let out = run_lint(&root).expect("lint fixture tree");
    let _ = std::fs::remove_dir_all(&root);
    out
}

fn rules(findings: &[Finding]) -> Vec<&str> {
    findings.iter().map(|f| f.rule.as_str()).collect()
}

#[test]
fn lock_cycle_fixture_trips() {
    let out = lint_tree(
        "cycle",
        &[(
            "rust/src/coordinator/state.rs",
            include_str!("fixtures/lint/lock_cycle.rs"),
        )],
    );
    assert_eq!(rules(&out.findings), ["lock-cycle"], "{:#?}", out.findings);
    assert!(
        out.findings[0].msg.contains("state.a") && out.findings[0].msg.contains("state.b"),
        "cycle message should name both locks: {}",
        out.findings[0].msg
    );
}

#[test]
fn lock_across_send_fixture_trips() {
    let out = lint_tree(
        "blocking",
        &[(
            "rust/src/coordinator/pipe.rs",
            include_str!("fixtures/lint/lock_blocking.rs"),
        )],
    );
    assert_eq!(rules(&out.findings), ["lock-blocking"], "{:#?}", out.findings);
    assert!(out.findings[0].msg.contains("send"), "{}", out.findings[0].msg);
}

#[test]
fn hot_path_alloc_fixture_trips() {
    let out = lint_tree(
        "alloc",
        &[(
            "rust/src/kan/engine.rs",
            include_str!("fixtures/lint/hot_alloc.rs"),
        )],
    );
    assert_eq!(rules(&out.findings), ["alloc"], "{:#?}", out.findings);
    assert!(
        out.findings[0].msg.contains("forward_into"),
        "{}",
        out.findings[0].msg
    );
}

#[test]
fn undocumented_error_code_fixture_trips() {
    let out = lint_tree(
        "drift",
        &[
            (
                "rust/src/coordinator/protocol.rs",
                include_str!("fixtures/lint/undocumented_code.rs"),
            ),
            (
                "docs/PROTOCOL.md",
                "# Protocol\n\nError codes:\n\n| code | meaning |\n|---|---|\n\
                 | `bad_thing` | something bad happened |\n",
            ),
        ],
    );
    assert_eq!(rules(&out.findings), ["doc-drift"], "{:#?}", out.findings);
    assert!(out.findings[0].msg.contains("mystery"), "{}", out.findings[0].msg);
}

#[test]
fn panic_and_poison_fixture_trips() {
    let out = lint_tree(
        "panic",
        &[(
            "rust/src/cluster/worker.rs",
            include_str!("fixtures/lint/panic_unwrap.rs"),
        )],
    );
    let mut got = rules(&out.findings);
    got.sort_unstable();
    assert_eq!(got, ["panic", "poison"], "{:#?}", out.findings);
}

#[test]
fn clean_fixture_passes() {
    let out = lint_tree(
        "clean",
        &[(
            "rust/src/coordinator/clean.rs",
            include_str!("fixtures/lint/clean.rs"),
        )],
    );
    assert!(out.clean(), "clean fixture should produce no findings: {:#?}", out.findings);
}

#[test]
fn reasonless_annotation_is_flagged() {
    let src = "\
pub fn f(v: Option<u32>) -> u32 {
    // lint: allow(panic)
    v.unwrap()
}
";
    let out = lint_tree("badann", &[("rust/src/obs/x.rs", src)]);
    assert_eq!(rules(&out.findings), ["bad-annotation"], "{:#?}", out.findings);
}

#[test]
fn shipped_tree_is_clean() {
    // CARGO_MANIFEST_DIR is <repo>/rust; the repo root is its parent
    let root: PathBuf = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .to_path_buf();
    let out = run_lint(&root).expect("lint shipped tree");
    assert!(out.files_scanned > 40, "expected a full tree scan, got {}", out.files_scanned);
    assert!(
        out.clean(),
        "shipped tree must pass its own lint:\n{}",
        kan_edge::analysis::render_human(&out.findings, out.files_scanned)
    );
    assert_eq!(
        out.allows_without_reason, 0,
        "every suppression in the tree must carry a reason"
    );
    assert!(
        out.allows > 0,
        "the tree carries reasoned suppressions; zero means annotation \
         collection silently broke"
    );
}
