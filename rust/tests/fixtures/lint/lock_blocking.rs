// Fixture: a mutex guard held across a channel send — the analyzer
// must report `lock-blocking`. Not compiled; consumed as text by
// tests/analysis.rs via include_str!.
use std::sync::mpsc::Sender;
use std::sync::Mutex;

pub struct Pipe {
    tx: Mutex<Sender<u32>>,
}

impl Pipe {
    pub fn push(&self, v: u32) {
        let tx = self.tx.lock_recover();
        let _ = tx.send(v);
    }
}
