// Fixture: a per-row allocation inside an engine steady-state function
// — the analyzer must report `alloc`. Not compiled; consumed as text by
// tests/analysis.rs via include_str!.
pub fn forward_into(xs: &[f32], out: &mut Vec<f32>) {
    let scratch: Vec<f32> = xs.to_vec();
    out.extend(scratch);
}
