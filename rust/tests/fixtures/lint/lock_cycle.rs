// Fixture: two mutexes acquired in opposite orders by two functions —
// the analyzer must report a `lock-cycle`. Not compiled; consumed as
// text by tests/analysis.rs via include_str!.
use std::sync::Mutex;

pub struct S {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl S {
    pub fn ab(&self) -> u32 {
        let ga = self.a.lock_recover();
        let gb = self.b.lock_recover();
        let v = *ga + *gb;
        drop(gb);
        drop(ga);
        v
    }

    pub fn ba(&self) -> u32 {
        let gb = self.b.lock_recover();
        let ga = self.a.lock_recover();
        let v = *ga + *gb;
        drop(ga);
        drop(gb);
        v
    }
}
