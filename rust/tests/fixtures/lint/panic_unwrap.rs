// Fixture: a bare poison-unwrap on a lock plus an unwrap on
// request-derived data — the analyzer must report `poison` for the
// first and `panic` for the second. Not compiled; consumed as text by
// tests/analysis.rs via include_str!.
use std::sync::Mutex;

pub struct W {
    state: Mutex<u32>,
}

impl W {
    pub fn read_state(&self) -> u32 {
        *self.state.lock().unwrap()
    }

    pub fn explode(&self, v: Option<u32>) -> u32 {
        v.unwrap()
    }
}
