// Fixture: `as_str` produces a wire error code the PROTOCOL.md table
// does not list — the analyzer must report `doc-drift`. Not compiled;
// consumed as text by tests/analysis.rs via include_str!.
pub enum ErrorCode {
    BadThing,
    Mystery,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadThing => "bad_thing",
            ErrorCode::Mystery => "mystery",
        }
    }
}
