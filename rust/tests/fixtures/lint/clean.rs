// Fixture: serving-path code written to policy — recover helpers for
// locks, a reasoned annotation for the one deliberate expect. The
// analyzer must report nothing. Not compiled; consumed as text by
// tests/analysis.rs via include_str!.
use std::sync::Mutex;

pub struct Clean {
    n: Mutex<u64>,
}

impl Clean {
    pub fn bump(&self) -> u64 {
        let mut g = self.n.lock_recover();
        *g += 1;
        *g
    }

    pub fn must(&self) -> u64 {
        // lint: allow(panic, "fixture: demonstrates a reasoned suppression")
        self.maybe().expect("fixture invariant")
    }

    fn maybe(&self) -> Option<u64> {
        Some(*self.n.lock_recover())
    }
}
