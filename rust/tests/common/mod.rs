//! Fixtures shared by the integration-test suites (`mod common;` in
//! each test file): temp artifact dirs, the schema-v2 test manifest
//! over synthetic KAN variants, and a digital-backend config. The
//! checkpoint JSON itself comes from
//! `kan_edge::kan::checkpoint::synthetic_checkpoint_json` so the
//! format-sensitive layer shape lives in exactly one place.

// each test binary compiles its own copy and uses a different subset
#![allow(dead_code)]
#![allow(clippy::field_reassign_with_default)]

use std::path::{Path, PathBuf};

use kan_edge::config::AppConfig;
use kan_edge::coordinator::BackendKind;
use kan_edge::registry::digest_file;

/// Fresh per-test directory under `suite` (wiped if it already exists).
pub fn tmp_dir(suite: &str, test: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(suite).join(test);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a schema-v2 manifest over models `(name, weights-file, version)`,
/// with correct digests computed from the files on disk.
pub fn write_manifest_v2(dir: &Path, models: &[(&str, &str, u32)]) {
    write_manifest_v2_with(dir, models, |_name, file| {
        digest_file(dir.join(file)).unwrap()
    })
}

/// Like [`write_manifest_v2`] with an arbitrary digest per model —
/// lets failure-injection tests record a wrong one.
pub fn write_manifest_v2_with(
    dir: &Path,
    models: &[(&str, &str, u32)],
    digest_of: impl Fn(&str, &str) -> String,
) {
    let entries: Vec<String> = models
        .iter()
        .map(|(name, file, version)| {
            let digest = digest_of(name, file);
            format!(
                r#""{name}":{{"kind":"kan","dims":[2,2],"g":1,"k":1,"num_params":8,
                    "val_acc":0.9,"weights":"{file}",
                    "meta":{{"version":{version},"digest":"{digest}",
                            "quant":{{"g":1,"k":1,"n_bits":8}},"accuracy":0.9}}}}"#
            )
        })
        .collect();
    let text = format!(
        r#"{{"schema_version":2,"format":1,"seed":0,
            "dataset":{{"num_features":2,"num_classes":2,"train":0,"val":0,"test":0}},
            "models":{{{}}},"sweep":[],"batch_sizes":[]}}"#,
        entries.join(",")
    );
    std::fs::write(dir.join("manifest.json"), text).unwrap();
}

/// Config pointing at `dir` with the digital backend and `default_model`.
pub fn test_config(dir: &Path, default_model: &str) -> AppConfig {
    let mut cfg = AppConfig::default();
    cfg.artifacts.dir = dir.to_string_lossy().into_owned();
    cfg.artifacts.model = default_model.to_string();
    cfg.server.backend = BackendKind::Digital;
    cfg
}
