//! Cross-client fairness and bounded-metrics integration tests.
//!
//! The load shape that motivated the scheduler: one client pushes a
//! large `infer_batch` through a small admission queue while other
//! clients submit single rows on their own connections. Under `fifo`
//! (the seed behavior) the batch holds the queue at capacity while it
//! drains, so the singletons draw `overloaded`; under `drr` the batch is
//! capped at its per-client quota and the round-robin drain interleaves,
//! so the same load admits every singleton. A slow backend makes the
//! contention deterministic instead of timing-dependent.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use kan_edge::client::KanClient;
use kan_edge::coordinator::backend::{
    BackendSpec, ExecOptions, ExecutionSession, RowOutput,
};
use kan_edge::coordinator::{
    BatchPolicy, InferenceService, Metrics, SchedMode, SchedulerOptions, ServeOptions,
    TcpServer,
};
use kan_edge::error::{Error, Result};

/// Echo backend that sleeps per batch: keeps the admission queue
/// occupied so the fifo-vs-drr contrast does not depend on machine
/// speed.
struct SlowEcho(Duration);

impl ExecutionSession for SlowEcho {
    fn name(&self) -> &str {
        "slow-echo"
    }

    fn spec(&self) -> BackendSpec {
        BackendSpec::synthetic(1)
    }

    fn run(&self, rows: Vec<Vec<f32>>, _opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
        std::thread::sleep(self.0);
        Ok(rows.iter().map(|r| vec![r[0]].into()).collect())
    }
}

fn slow_server(mode: SchedMode) -> TcpServer {
    let opts = ServeOptions {
        policy: BatchPolicy { max_batch: 4, deadline: Duration::from_micros(200) },
        queue_depth: 8,
        workers: 1,
        scheduler: SchedulerOptions {
            mode,
            client_quota: 4,
            fairness_window: 2,
        },
    };
    let svc =
        InferenceService::start(Arc::new(SlowEcho(Duration::from_millis(2))), opts);
    TcpServer::spawn("127.0.0.1:0", Arc::new(svc)).unwrap()
}

/// One batch connection pushing 128 rows (≈ 64 ms of sustained queue
/// pressure at 4 rows / 2 ms) + one singleton connection probing during
/// that window. Returns (singleton rejections, singleton successes).
fn mixed_load(mode: SchedMode) -> (u64, usize) {
    let server = slow_server(mode);
    let addr = server.addr;
    let batch = std::thread::spawn(move || {
        let mut client = KanClient::connect(addr).unwrap();
        let rows: Vec<Vec<f32>> = (0..128).map(|i| vec![i as f32]).collect();
        client.infer_batch(None, rows).unwrap()
    });
    // let the batch saturate the queue before probing
    std::thread::sleep(Duration::from_millis(8));
    let mut client = KanClient::connect(addr).unwrap();
    let mut rejections = 0u64;
    let mut successes = 0usize;
    for _ in 0..12 {
        match client.infer(&[7.0]) {
            Ok(out) => {
                assert_eq!(out.logits[0], 7.0);
                successes += 1;
            }
            Err(Error::Overloaded { .. }) => rejections += 1,
            Err(e) => panic!("unexpected singleton error: {e}"),
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let (model, results) = batch.join().unwrap();
    assert_eq!(model, "default");
    assert_eq!(results.len(), 128);
    for (i, row) in results.iter().enumerate() {
        assert_eq!(row.logits[0], i as f32, "batch row order broken at {i}");
    }
    server.shutdown();
    (rejections, successes)
}

#[test]
fn fifo_starves_singletons_under_batch_load() {
    let (rejections, _successes) = mixed_load(SchedMode::Fifo);
    assert!(
        rejections >= 1,
        "fifo admitted every singleton under saturation — the starvation \
         scenario this suite contrasts against did not reproduce"
    );
}

#[test]
fn drr_admits_every_singleton_at_the_same_load() {
    let (rejections, successes) = mixed_load(SchedMode::Drr);
    assert_eq!(
        rejections, 0,
        "drr rejected a singleton that was within quota and capacity"
    );
    assert_eq!(successes, 12);
}

/// Echo backend that blocks until the test opens its gate — freezes the
/// pipeline so admission counts are exact, not timing-dependent.
struct Gated {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl ExecutionSession for Gated {
    fn name(&self) -> &str {
        "gated"
    }

    fn spec(&self) -> BackendSpec {
        BackendSpec::synthetic(1)
    }

    fn run(&self, rows: Vec<Vec<f32>>, _opts: &[ExecOptions]) -> Result<Vec<RowOutput>> {
        let (m, cv) = &*self.gate;
        let mut open = m.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        Ok(rows.iter().map(|r| vec![r[0]].into()).collect())
    }
}

#[test]
fn v2_quota_rejection_reaches_client_with_retry_hint() {
    let gate: Arc<(Mutex<bool>, Condvar)> = Arc::new((Mutex::new(false), Condvar::new()));
    let opts = ServeOptions {
        policy: BatchPolicy { max_batch: 1, deadline: Duration::from_micros(100) },
        queue_depth: 8,
        workers: 1,
        scheduler: SchedulerOptions {
            mode: SchedMode::Drr,
            client_quota: 1,
            fairness_window: 1,
        },
    };
    let svc = InferenceService::start(Arc::new(Gated { gate: gate.clone() }), opts);
    let server = TcpServer::spawn("127.0.0.1:0", Arc::new(svc)).unwrap();
    let mut client = KanClient::connect(server.addr).unwrap();

    // with the backend gated, the pipeline absorbs at most 4 rows
    // (worker + batch channel + batcher) and the queue holds at most the
    // quota (1): of 8 pipelined submits, at least 3 MUST be rejected —
    // and nothing can complete, so the first response is a rejection
    for i in 0..8 {
        client.submit(None, &[i as f32]).unwrap();
    }
    let (_id, outcome) = client.poll().unwrap();
    let mut rejections = 1u32;
    match outcome {
        Err(Error::Overloaded { message, retry_after_ms }) => {
            assert!(message.contains("quota"), "{message}");
            assert!(retry_after_ms >= 1, "hint must be a usable backoff");
        }
        other => panic!("expected an overloaded rejection, got {other:?}"),
    }

    // open the gate: every admitted request completes normally
    {
        let (m, cv) = &*gate;
        *m.lock().unwrap() = true;
        cv.notify_all();
    }
    let mut successes = 0u32;
    for _ in 0..7 {
        let (_id, outcome) = client.poll().unwrap();
        match outcome {
            Ok(_) => successes += 1,
            Err(Error::Overloaded { .. }) => rejections += 1,
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(successes + rejections, 8);
    assert!(successes >= 1, "the first admission cannot have been rejected");
    assert!(
        rejections >= 3,
        "absorption bound violated: only {rejections} rejections"
    );
    server.shutdown();
}

// ---- bounded metrics --------------------------------------------------------

#[test]
fn metrics_stay_bounded_after_100k_requests() {
    let m = Metrics::new();
    for i in 0..100_000u64 {
        m.record_request(
            Duration::from_micros(i + 1),
            Duration::from_micros(i % 500),
        );
    }
    let (retained, seen) = m.latency_sample_state();
    assert!(
        retained <= 1024,
        "reservoir leaked: {retained} samples retained"
    );
    assert_eq!(seen, 100_000);
    // counters stay exact while the distribution is sampled
    let r = m.report();
    assert_eq!(r.requests, 100_000);
}

#[test]
fn sampled_percentiles_track_the_exact_distribution() {
    // known distribution: latencies uniform over 1..=100_000 µs, so the
    // exact p50 is 50_000 and the exact p99 is 99_000
    let m = Metrics::new();
    for i in 0..100_000u64 {
        m.record_request(Duration::from_micros(i + 1), Duration::from_micros(1));
    }
    let r = m.report();
    // 1024 retained samples: σ(rank) ≈ 1.6 % at p50 and ≈ 0.31 % at
    // p99, so these bounds are ≈ 5σ and ≈ 8σ — and the sampler is
    // deterministic (fixed rng seed), so this can never flake
    let p50 = r.latency_p50_us as i64;
    assert!((p50 - 50_000).abs() <= 8_000, "sampled p50 {p50} vs exact 50000");
    let p99 = r.latency_p99_us as i64;
    assert!((p99 - 99_000).abs() <= 2_500, "sampled p99 {p99} vs exact 99000");
}
