//! Integration tests over the real artifacts (`make artifacts` must have
//! run; tests that need artifacts skip gracefully when absent so `cargo
//! test` stays usable on a fresh checkout).

#![allow(clippy::field_reassign_with_default)]

use std::path::Path;
use std::sync::Arc;

use kan_edge::acim::{AcimOptions, ArrayConfig};
use kan_edge::baseline::MlpModel;
use kan_edge::config::AppConfig;
use kan_edge::coordinator::batcher::BatchPolicy;
use kan_edge::coordinator::{
    build_acim_with_calib, build_session, BackendKind, ExecutionSession,
    InferenceService, ServeOptions,
};
use kan_edge::kan::checkpoint::{Dataset, Manifest};
use kan_edge::kan::QuantKanModel;
use kan_edge::mapping::MappingStrategy;

fn artifacts() -> Option<&'static str> {
    if Path::new("../artifacts/manifest.json").exists() {
        Some("../artifacts")
    } else {
        None
    }
}

macro_rules! need_artifacts {
    () => {
        match artifacts() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

#[test]
fn manifest_and_dataset_load() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(dir).unwrap();
    assert!(manifest.models.contains_key("kan1"));
    assert!(manifest.models.contains_key("kan2"));
    assert!(manifest.models.contains_key("mlp"));
    assert_eq!(manifest.sweep.len(), 4);
    let ds = Dataset::load(dir).unwrap();
    assert_eq!(ds.num_features, 17);
    assert_eq!(ds.num_classes, 14);
    assert_eq!(ds.test_y.len() * 17, ds.test_x.len());
}

#[test]
fn digital_accuracy_matches_python_export() {
    // the rust integer dataflow must agree with the JAX quantized forward
    // that produced `quant_test_acc` — same LUTs, same codes, same math
    let dir = need_artifacts!();
    let manifest = Manifest::load(dir).unwrap();
    let ds = Dataset::load(dir).unwrap();
    for name in ["kan1", "kan2"] {
        let entry = &manifest.models[name];
        let model =
            QuantKanModel::load(format!("{dir}/{}", entry.weights)).unwrap();
        let acc = model.accuracy(&ds);
        let expect = entry.quant_test_acc.unwrap();
        assert!(
            (acc - expect).abs() < 0.02,
            "{name}: rust digital {acc:.4} vs python quant {expect:.4}"
        );
    }
}

#[test]
fn mlp_accuracy_matches_python_export() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(dir).unwrap();
    let ds = Dataset::load(dir).unwrap();
    let entry = &manifest.models["mlp"];
    let model = MlpModel::load(format!("{dir}/{}", entry.weights)).unwrap();
    let acc = model.accuracy(&ds);
    let expect = entry.test_acc.unwrap();
    assert!(
        (acc - expect).abs() < 0.005,
        "mlp: rust {acc:.4} vs python {expect:.4}"
    );
}

#[cfg(all(feature = "pjrt", feature = "xla"))]
#[test]
fn pjrt_matches_digital_reference() {
    // the AOT HLO graph and the rust integer dataflow implement the same
    // quantized model; predictions must agree on (almost) every sample
    let dir = need_artifacts!();
    let manifest = Manifest::load(dir).unwrap();
    let ds = Dataset::load(dir).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts.dir = dir.to_string();
    cfg.server.backend = BackendKind::Pjrt;
    let pjrt = build_session(&cfg, &manifest, "kan1").unwrap();
    let digital = QuantKanModel::load(format!("{dir}/kan1.weights.json")).unwrap();

    let rows: Vec<Vec<f32>> =
        ds.test_rows().take(128).map(|(r, _)| r.to_vec()).collect();
    let outs = pjrt.infer_logits(rows.clone()).unwrap();
    let mut agree = 0;
    for (row, out) in rows.iter().zip(&outs) {
        let p_pjrt = kan_edge::kan::argmax(
            &out.iter().map(|&v| v as f64).collect::<Vec<_>>(),
        );
        let p_dig = kan_edge::kan::argmax(&digital.forward(row));
        if p_pjrt == p_dig {
            agree += 1;
        }
        // logits must also be numerically close (f32 vs f64 accumulation)
        let d = digital.forward(row);
        for (a, b) in out.iter().zip(&d) {
            assert!(
                (*a as f64 - b).abs() < 1e-2,
                "logit mismatch: {a} vs {b}"
            );
        }
    }
    assert!(agree >= 127, "pjrt vs digital agreement {agree}/128");
}

#[test]
fn serving_pipeline_end_to_end_digital() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(dir).unwrap();
    let ds = Dataset::load(dir).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts.dir = dir.to_string();
    cfg.server.backend = BackendKind::Digital;
    let backend = build_session(&cfg, &manifest, "kan1").unwrap();
    let svc = InferenceService::start(
        backend,
        ServeOptions {
            policy: BatchPolicy {
                max_batch: 16,
                deadline: std::time::Duration::from_millis(1),
            },
            queue_depth: 256,
            workers: 2,
            ..ServeOptions::default()
        },
    );
    let mut correct = 0;
    let total = 200;
    for (row, label) in ds.test_rows().take(total) {
        let logits = svc.infer(row.to_vec()).unwrap();
        let pred = kan_edge::kan::argmax(
            &logits.iter().map(|&v| v as f64).collect::<Vec<_>>(),
        );
        if pred == label as usize {
            correct += 1;
        }
    }
    // sequential requests, so batching is trivial, but accuracy must hold
    assert!(
        correct as f64 / total as f64 > 0.7,
        "served accuracy {correct}/{total}"
    );
    assert_eq!(svc.metrics.report().requests, total as u64);
}

#[test]
fn acim_sam_beats_uniform_on_large_array() {
    let dir = need_artifacts!();
    let ds = Dataset::load(dir).unwrap();
    let qk =
        QuantKanModel::load(format!("{dir}/sweep/kan_g30.weights.json")).unwrap();
    // IR-drop-dominated regime (the Fig 12 configuration): deterministic,
    // position-driven; see benches/fig12_sam.rs
    let opts = AcimOptions {
        array: ArrayConfig {
            rows: 512,
            r_wire_ohm: 6.0,
            ..ArrayConfig::default()
        },
        adc_bits: 12,
        irdrop: true,
        noise: false,
        ..Default::default()
    };
    let sam = build_acim_with_calib(&qk, opts, &ds, MappingStrategy::Sam)
        .unwrap()
        .accuracy(&ds);
    let uni = build_acim_with_calib(&qk, opts, &ds, MappingStrategy::Uniform)
        .unwrap()
        .accuracy(&ds);
    assert!(
        sam >= uni,
        "KAN-SAM ({sam:.4}) should not lose to uniform ({uni:.4})"
    );
}

#[test]
fn acim_without_nonidealities_matches_digital() {
    let dir = need_artifacts!();
    let ds = Dataset::load(dir).unwrap();
    let qk = QuantKanModel::load(format!("{dir}/kan1.weights.json")).unwrap();
    let digital_acc = qk.accuracy(&ds);
    let opts = AcimOptions {
        array: ArrayConfig { r_wire_ohm: 0.0, ..ArrayConfig::with_rows(1024) },
        adc_bits: 12,
        adc_fs_factor: 1.0,
        irdrop: false,
        noise: false,
        seed: 1,
    };
    let acim_acc = build_acim_with_calib(&qk, opts, &ds, MappingStrategy::Uniform)
        .unwrap()
        .accuracy(&ds);
    assert!(
        (acim_acc - digital_acc).abs() < 0.02,
        "ideal ACIM {acim_acc:.4} vs digital {digital_acc:.4}"
    );
}

#[test]
fn backend_output_dims_consistent() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(dir).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts.dir = dir.to_string();
    let backends: &[BackendKind] =
        if cfg!(all(feature = "pjrt", feature = "xla")) {
            &[BackendKind::Digital, BackendKind::Pjrt]
        } else {
            &[BackendKind::Digital]
        };
    for backend_kind in backends.iter().copied() {
        cfg.server.backend = backend_kind;
        let be = build_session(&cfg, &manifest, "kan1").unwrap();
        assert_eq!(be.spec().output_dim, 14, "{backend_kind}");
        assert_eq!(be.spec().kind, backend_kind);
        let out = be.infer_logits(vec![vec![0.0; 17]]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 14);
        assert!(out[0].iter().all(|v| v.is_finite()));
    }
}

#[test]
fn unknown_model_is_clear_error() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(dir).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts.dir = dir.to_string();
    let err = match build_session(&cfg, &manifest, "nope") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("nope"));
}

#[test]
fn concurrent_serving_under_load() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(dir).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts.dir = dir.to_string();
    cfg.server.backend = BackendKind::Digital;
    let backend = build_session(&cfg, &manifest, "kan1").unwrap();
    let svc = InferenceService::start(
        backend,
        ServeOptions {
            policy: BatchPolicy {
                max_batch: 32,
                deadline: std::time::Duration::from_micros(200),
            },
            queue_depth: 2048,
            workers: 4,
            ..ServeOptions::default()
        },
    );
    let svc = Arc::new(svc);
    let mut handles = Vec::new();
    for c in 0..8 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..50 {
                let x = vec![((c * 50 + i) % 10) as f32 * 0.1 - 0.5; 17];
                let out = svc.infer(x).unwrap();
                assert_eq!(out.len(), 14);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let r = svc.metrics.report();
    assert_eq!(r.requests, 400);
    assert!(r.mean_batch >= 1.0);
}
