//! Property-based tests over coordinator and substrate invariants.
//!
//! The offline image carries no proptest; these use the crate's own seeded
//! PRNG to sweep randomized cases — same spirit (many random inputs, one
//! invariant per test), fully deterministic.

use kan_edge::acim::{mac_with_irdrop, ArrayConfig, Crossbar};
use kan_edge::kan::spline;
use kan_edge::mapping::{build_mapping, is_permutation, MappingStrategy};
use kan_edge::quant::{solve_ld, AspSpec, ShLut};
use kan_edge::util::json::Value;
use kan_edge::util::Rng;

const CASES: usize = 200;

#[test]
fn prop_quantize_grid_alignment() {
    // for any (g, k, n, range): knot boundaries align with code boundaries
    let mut rng = Rng::new(11);
    for _ in 0..CASES {
        let n = [6u32, 8, 10][(rng.next_u64() % 3) as usize];
        let g = rng.int_range(1, (1 << n) as i64) as u32;
        let k = rng.int_range(1, 4) as u32;
        let lo = rng.range(-5.0, 2.0);
        let hi = lo + rng.range(0.1, 8.0);
        let spec = AspSpec::build(g, k, n, lo, hi).unwrap();
        for j in 0..g.min(20) {
            let knot = lo + j as f64 * spec.knot_spacing();
            let q = spec.quantize(knot);
            assert_eq!(q >> spec.ld, j, "g={g} n={n} j={j}");
            assert_eq!(q & (spec.levels_per_interval() - 1), 0);
        }
    }
}

#[test]
fn prop_decompose_roundtrip() {
    let mut rng = Rng::new(12);
    for _ in 0..CASES {
        let n = 8u32;
        let g = rng.int_range(1, 256) as u32;
        let spec = AspSpec::build(g, 3, n, 0.0, 1.0).unwrap();
        let q = rng.int_range(0, (spec.range() - 1) as i64) as u32;
        let (j, l) = spec.decompose(q);
        assert_eq!(j * spec.levels_per_interval() + l, q);
        assert!(j < spec.g);
    }
}

#[test]
fn prop_sh_lut_mirror_equals_direct() {
    let mut rng = Rng::new(13);
    for _ in 0..60 {
        let g = rng.int_range(2, 64) as u32;
        let k = rng.int_range(1, 4) as u32;
        let spec = AspSpec::build(g, k, 8, -1.0, 1.0).unwrap();
        let lut = ShLut::build(&spec, 8);
        let lvl = lut.full_rows() as u32;
        let l = rng.int_range(0, (lvl - 1) as i64) as u32;
        let t = rng.int_range(0, k as i64) as u32;
        let direct = spline::active_basis(l as f64 / lvl as f64, k as usize)
            [t as usize];
        let want = (direct * 255.0).round() as u32;
        assert_eq!(lut.lookup(l, t), want, "g={g} k={k} l={l} t={t}");
    }
}

#[test]
fn prop_partition_of_unity_quantized() {
    // quantized LUT rows sum to 255 +- rounding for any geometry
    let mut rng = Rng::new(14);
    for _ in 0..60 {
        let g = rng.int_range(1, 200) as u32;
        let k = rng.int_range(1, 4) as u32;
        let spec = AspSpec::build(g, k, 8, 0.0, 1.0).unwrap();
        let lut = ShLut::build(&spec, 8);
        for l in 0..lut.full_rows() as u32 {
            let sum: u32 = lut.row(l).iter().sum();
            assert!(
                (255i64 - sum as i64).abs() <= 1 + k as i64,
                "g={g} k={k} l={l}: sum {sum}"
            );
        }
    }
}

#[test]
fn prop_solve_ld_maximality() {
    let mut rng = Rng::new(15);
    for _ in 0..CASES {
        let n = rng.int_range(4, 12) as u32;
        let g = rng.int_range(1, (1 << n) as i64) as u32;
        let ld = solve_ld(g, n).unwrap();
        assert!((g as u64) << ld <= 1u64 << n);
        assert!((g as u64) << (ld + 1) > 1u64 << n);
    }
}

#[test]
fn prop_sam_mapping_is_permutation() {
    let mut rng = Rng::new(16);
    for _ in 0..CASES {
        let rows = rng.int_range(1, 400) as usize;
        let tile = rng.int_range(1, 300) as usize;
        let probs: Vec<f64> = (0..rows).map(|_| rng.uniform()).collect();
        for strat in [
            MappingStrategy::Uniform,
            MappingStrategy::Sam,
            MappingStrategy::WorstCase,
        ] {
            let m = build_mapping(&probs, tile, strat);
            assert!(is_permutation(&m), "{strat:?} rows={rows} tile={tile}");
        }
    }
}

#[test]
fn prop_sam_clamp_slot_gets_max_probability() {
    let mut rng = Rng::new(17);
    for _ in 0..CASES {
        let rows = rng.int_range(2, 200) as usize;
        let probs: Vec<f64> = (0..rows).map(|_| rng.uniform()).collect();
        let m = build_mapping(&probs, rows, MappingStrategy::Sam); // one tile
        let max = probs.iter().cloned().fold(f64::MIN, f64::max);
        assert_eq!(probs[m[0]], max);
    }
}

#[test]
fn prop_irdrop_bounded_by_ideal() {
    // for any programming and drive pattern: 0 <= |I_drop| <= |I_ideal|
    // column-wise when all weights share a sign
    let mut rng = Rng::new(18);
    for _ in 0..40 {
        let rows = rng.int_range(4, 256) as usize;
        let cfg = ArrayConfig {
            r_wire_ohm: rng.range(0.1, 5.0),
            ..ArrayConfig::with_rows(rows)
        };
        let w: Vec<i32> = (0..rows).map(|_| rng.int_range(0, 127) as i32).collect();
        let xb = Crossbar::program(cfg, &w, rows, 1, 127.0).unwrap();
        let drives: Vec<f64> = (0..rows).map(|_| rng.uniform()).collect();
        let ideal = xb.mac_ideal(&drives)[0];
        let real = mac_with_irdrop(&xb, &drives)[0];
        assert!(real >= -1e-9, "negative positive-column current");
        assert!(real <= ideal + 1e-9, "IR-drop increased current");
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    // random JSON trees survive write -> parse
    let mut rng = Rng::new(19);
    for _ in 0..CASES {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Value::parse(&text).unwrap_or_else(|e| {
            panic!("reparse failed: {e}\ntext: {text}");
        });
        assert_eq!(v, back, "roundtrip mismatch for {text}");
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Value {
    match rng.int_range(0, if depth == 0 { 2 } else { 4 }) {
        0 => Value::Int(rng.int_range(-1_000_000, 1_000_000)),
        1 => Value::Float((rng.range(-1e6, 1e6) * 1e3).round() / 1e3),
        2 => {
            let n = rng.int_range(0, 8) as usize;
            Value::Str(
                (0..n)
                    .map(|_| {
                        ['a', 'é', '"', '\\', '\n', 'z', '😀']
                            [(rng.next_u64() % 7) as usize]
                    })
                    .collect(),
            )
        }
        3 => Value::Array(
            (0..rng.int_range(0, 5))
                .map(|_| random_json(rng, depth - 1))
                .collect(),
        ),
        _ => {
            let mut map = std::collections::BTreeMap::new();
            for i in 0..rng.int_range(0, 5) {
                map.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Value::Object(map)
        }
    }
}

#[test]
fn prop_spline_partition_of_unity_everywhere() {
    let mut rng = Rng::new(20);
    for _ in 0..CASES {
        let g = rng.int_range(1, 64) as usize;
        let k = rng.int_range(0, 4) as usize;
        let z = rng.range(0.0, g as f64 - 1e-9);
        let sum: f64 = spline::basis_functions(z, g, k).iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "g={g} k={k} z={z}: {sum}");
    }
}

#[test]
fn prop_spline_nonnegative_and_bounded() {
    let mut rng = Rng::new(21);
    for _ in 0..CASES {
        let k = rng.int_range(0, 5) as usize;
        let s = rng.range(-1.0, k as f64 + 2.0);
        let v = spline::cardinal_bspline(s, k);
        assert!(v >= 0.0);
        assert!(v <= 1.0 + 1e-12);
    }
}
