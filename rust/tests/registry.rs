//! Registry & multi-model serving integration tests.
//!
//! These run fully offline: they synthesize tiny-but-valid KAN
//! checkpoints (G=1, K=1, LD=2; residual-path weights chosen so each
//! variant prefers a different class) and drive the whole stack — v1/v2
//! manifests, content digests, the registry's lazy load + LRU, per-model
//! metrics, the TCP `"model"` routing field, and hot reload.

#![allow(clippy::field_reassign_with_default)]

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::sync::Arc;

use kan_edge::coordinator::{Dispatch, TcpServer};
use kan_edge::kan::checkpoint::synthetic_checkpoint_json as kan_variant_json;
use kan_edge::registry::{digest_file, ModelManifest, ModelRegistry};
use kan_edge::util::json::Value;

mod common;
use common::{test_config, write_manifest_v2, write_manifest_v2_with};

fn tmp_dir(test: &str) -> PathBuf {
    common::tmp_dir("kan_edge_registry_tests", test)
}

/// Two-variant artifacts dir: model "a" favors class 0, "b" favors 1.
fn two_variant_dir(test: &str) -> PathBuf {
    let dir = tmp_dir(test);
    std::fs::write(dir.join("a.weights.json"), kan_variant_json("a", 0)).unwrap();
    std::fs::write(dir.join("b.weights.json"), kan_variant_json("b", 1)).unwrap();
    write_manifest_v2(&dir, &[("a", "a.weights.json", 1), ("b", "b.weights.json", 1)]);
    dir
}

/// One JSON-lines request over an open connection.
fn request(
    conn: &mut std::net::TcpStream,
    reader: &mut BufReader<std::net::TcpStream>,
    body: &str,
) -> Value {
    conn.write_all(body.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Value::parse(&line).unwrap()
}

#[test]
fn two_variants_served_concurrently_over_one_socket() {
    let dir = two_variant_dir("two_variants");
    let registry = ModelRegistry::open(&test_config(&dir, "a")).unwrap();
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
    let addr = server.addr;

    let per_client: u64 = 10;
    let mut handles = Vec::new();
    for (model, expect_class) in [("a", 0i64), ("b", 1i64)] {
        handles.push(std::thread::spawn(move || {
            let conn = std::net::TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut conn = conn;
            for _ in 0..per_client {
                let v = request(
                    &mut conn,
                    &mut reader,
                    &format!(r#"{{"model": "{model}", "features": [0.5, 0.5]}}"#),
                );
                assert_eq!(
                    v.get("class").unwrap().as_i64().unwrap(),
                    expect_class,
                    "model {model}"
                );
                assert_eq!(
                    v.get("model").unwrap().as_str().unwrap(),
                    format!("{model}@1")
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // per-model metrics: one report per variant, correct counts
    let reports = registry.metrics();
    let get = |id: &str| {
        reports
            .iter()
            .find(|(rid, _)| rid == id)
            .unwrap_or_else(|| panic!("no metrics for {id}: {reports:?}"))
            .1
            .clone()
    };
    assert_eq!(get("a@1").requests, per_client);
    assert_eq!(get("b@1").requests, per_client);
    // exact aggregate rollup across both models
    assert_eq!(registry.aggregate_metrics().requests, 2 * per_client);

    // default model (no "model" field) routes to "a"
    let conn = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut conn = conn;
    let v = request(&mut conn, &mut reader, r#"{"features": [0.5, 0.5]}"#);
    assert_eq!(v.get("model").unwrap().as_str().unwrap(), "a@1");
    server.shutdown();
}

#[test]
fn hot_reload_switches_traffic_without_dropping_requests() {
    let dir = two_variant_dir("hot_reload");
    let registry = ModelRegistry::open(&test_config(&dir, "a")).unwrap();

    // v1 of "a" favors class 0
    let (id, logits) = registry.infer(Some("a"), vec![0.5, 0.5]).unwrap();
    assert_eq!(id, "a@1");
    assert!(logits[0] > logits[1]);

    // publish v2: flipped weights, bumped version, new digest
    std::fs::write(dir.join("a.weights.json"), kan_variant_json("a", 1)).unwrap();
    write_manifest_v2(&dir, &[("a", "a.weights.json", 2), ("b", "b.weights.json", 1)]);

    // fire a background burst while the swap happens: every request must
    // complete (old or new version — never an error, never dropped)
    let reg2 = registry.clone();
    let burst = std::thread::spawn(move || {
        for _ in 0..50 {
            let (_, l) = reg2.infer(Some("a"), vec![0.5, 0.5]).unwrap();
            assert_eq!(l.len(), 2);
        }
    });
    let swapped = registry.poll_reload().unwrap();
    burst.join().unwrap();
    assert_eq!(swapped, vec!["a@2".to_string()]);

    // traffic now hits v2 (class flipped), and the id says so
    let (id, logits) = registry.infer(Some("a"), vec![0.5, 0.5]).unwrap();
    assert_eq!(id, "a@2");
    assert!(logits[1] > logits[0]);

    // version pinning: the retired version is refused with a clear error
    let err = registry.infer(Some("a@1"), vec![0.5, 0.5]).unwrap_err().to_string();
    assert!(err.contains("version 2"), "{err}");
    // both versions kept their metrics for the rollup
    let ids: Vec<String> = registry.metrics().into_iter().map(|(id, _)| id).collect();
    assert!(ids.contains(&"a@1".to_string()) && ids.contains(&"a@2".to_string()));

    // a second poll with nothing changed is a no-op
    assert!(registry.poll_reload().unwrap().is_empty());
}

#[test]
fn digest_mismatch_refuses_to_serve() {
    let dir = tmp_dir("digest_mismatch");
    std::fs::write(dir.join("a.weights.json"), kan_variant_json("a", 0)).unwrap();
    write_manifest_v2_with(&dir, &[("a", "a.weights.json", 1)], |_, _| {
        "fnv64:00000000000000ff".to_string()
    });
    let registry = ModelRegistry::open(&test_config(&dir, "a")).unwrap();
    let err = registry.infer(None, vec![0.5, 0.5]).unwrap_err().to_string();
    assert!(err.contains("digest mismatch"), "{err}");
}

#[test]
fn manifest_weights_shape_mismatch_detected() {
    let dir = tmp_dir("shape_mismatch");
    std::fs::write(dir.join("a.weights.json"), kan_variant_json("a", 0)).unwrap();
    let digest = digest_file(dir.join("a.weights.json")).unwrap();
    // manifest claims 3 outputs; the checkpoint produces 2
    let text = format!(
        r#"{{"schema_version":2,"format":1,"seed":0,
            "dataset":{{"num_features":2,"num_classes":3,"train":0,"val":0,"test":0}},
            "models":{{"a":{{"kind":"kan","dims":[2,3],"g":1,"k":1,"num_params":8,
               "val_acc":0.9,"weights":"a.weights.json",
               "meta":{{"version":1,"digest":"{digest}"}}}}}},
            "sweep":[],"batch_sizes":[]}}"#
    );
    std::fs::write(dir.join("manifest.json"), text).unwrap();
    let registry = ModelRegistry::open(&test_config(&dir, "a")).unwrap();
    let err = registry.infer(None, vec![0.5, 0.5]).unwrap_err().to_string();
    assert!(err.contains("outputs") || err.contains("shape"), "{err}");
}

#[test]
fn unknown_schema_version_rejected_at_open() {
    let dir = tmp_dir("unknown_schema");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"schema_version":42,"format":1,"seed":0,
            "dataset":{"num_features":1,"num_classes":1,"train":0,"val":0,"test":0},
            "models":{},"sweep":[],"batch_sizes":[]}"#,
    )
    .unwrap();
    let err = ModelRegistry::open(&test_config(&dir, "a"))
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("42") && err.contains("supports"), "{err}");
}

#[test]
fn corrupt_manifest_is_clear_error() {
    let dir = tmp_dir("corrupt_manifest");
    std::fs::write(dir.join("manifest.json"), "{\"schema_version\": 2,").unwrap();
    let err = ModelRegistry::open(&test_config(&dir, "a"))
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("manifest.json"), "{err}");
}

#[test]
fn unknown_model_and_bad_spec_are_clear_errors() {
    let dir = two_variant_dir("unknown_model");
    let registry = ModelRegistry::open(&test_config(&dir, "a")).unwrap();
    let err = registry.infer(Some("zzz"), vec![0.5, 0.5]).unwrap_err().to_string();
    assert!(err.contains("zzz") && err.contains("not in manifest"), "{err}");
    let err = registry.infer(Some("a@x"), vec![0.5, 0.5]).unwrap_err().to_string();
    assert!(err.contains("integer"), "{err}");
}

#[test]
fn lru_evicts_least_recent_backend() {
    let dir = two_variant_dir("lru_evict");
    let mut cfg = test_config(&dir, "a");
    cfg.registry.max_loaded = 1;
    let registry = ModelRegistry::open(&cfg).unwrap();

    registry.infer(Some("a"), vec![0.5, 0.5]).unwrap();
    let live_a: Vec<bool> = registry.models().iter().map(|m| m.live).collect();
    assert_eq!(live_a, vec![true, false]); // sorted: a, b

    // loading "b" evicts "a" (cap 1)
    registry.infer(Some("b"), vec![0.5, 0.5]).unwrap();
    let live_b: Vec<bool> = registry.models().iter().map(|m| m.live).collect();
    assert_eq!(live_b, vec![false, true]);

    // evicted model reloads transparently on the next request
    let (id, _) = registry.infer(Some("a"), vec![0.5, 0.5]).unwrap();
    assert_eq!(id, "a@1");
}

#[test]
fn publish_bootstraps_fresh_registry_and_bumps_versions() {
    let dir = tmp_dir("publish");
    ModelManifest::empty().save(&dir).unwrap();
    let registry = ModelRegistry::open(&test_config(&dir, "a")).unwrap();

    // first publish: version 1, weights land in the content store
    let src = dir.join("incoming.weights.json");
    std::fs::write(&src, kan_variant_json("a", 0)).unwrap();
    let (name, meta) = registry.publish_file(&src, None, None).unwrap();
    assert_eq!((name.as_str(), meta.version), ("a", 1));
    let digest1 = meta.digest.clone().unwrap();
    assert!(registry.store().contains(&digest1));
    assert_eq!(meta.quant.unwrap().g, 1);
    assert_eq!(meta.accuracy, Some(0.9));

    // serving works straight out of the store (content-addressed path)
    let (id, logits) = registry.infer(Some("a"), vec![0.5, 0.5]).unwrap();
    assert_eq!(id, "a@1");
    assert!(logits[0] > logits[1]);

    // second publish with different content: version bumps, digest changes,
    // and the live pipeline is hot-swapped
    std::fs::write(&src, kan_variant_json("a", 1)).unwrap();
    let (_, meta2) = registry.publish_file(&src, None, None).unwrap();
    assert_eq!(meta2.version, 2);
    assert_ne!(meta2.digest.as_ref().unwrap(), &digest1);
    let (id, logits) = registry.infer(Some("a"), vec![0.5, 0.5]).unwrap();
    assert_eq!(id, "a@2");
    assert!(logits[1] > logits[0]);

    // the on-disk manifest is now schema v2 and a fresh registry agrees
    let reloaded = ModelManifest::load(&dir).unwrap();
    assert_eq!(reloaded.schema_version, 2);
    assert_eq!(reloaded.meta_for("a").version, 2);

    // stale version numbers are refused
    let err = registry.publish_file(&src, None, Some(2)).unwrap_err().to_string();
    assert!(err.contains("must be greater"), "{err}");
}

#[test]
fn v1_manifest_still_serves() {
    // backwards compatibility: a flat aot.py-style manifest (no
    // schema_version, no meta) serves with implicit version 1
    let dir = tmp_dir("v1_compat");
    std::fs::write(dir.join("a.weights.json"), kan_variant_json("a", 0)).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format":1,"seed":0,
            "dataset":{"num_features":2,"num_classes":2,"train":0,"val":0,"test":0},
            "models":{"a":{"kind":"kan","dims":[2,2],"g":1,"k":1,"num_params":8,
               "val_acc":0.9,"weights":"a.weights.json"}},
            "sweep":[],"batch_sizes":[]}"#,
    )
    .unwrap();
    let registry = ModelRegistry::open(&test_config(&dir, "a")).unwrap();
    let (id, logits) = registry.infer(None, vec![0.5, 0.5]).unwrap();
    assert_eq!(id, "a@1");
    assert_eq!(logits.len(), 2);
}
