//! Rollout-plane integration tests (`docs/ROLLOUT.md`).
//!
//! Each test publishes a v1 "a" variant, loads it live, hot-swaps a v2
//! in (shelving v1 as the warm baseline), and drives the staged
//! canary controller over live TCP: deterministic split fractions,
//! auto-promote under a clean canary, instant auto-rollback on real
//! divergence with zero dropped requests, and state-machine
//! persistence across a registry hot-reload poll.

#![allow(clippy::field_reassign_with_default)]

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kan_edge::client::KanClient;
use kan_edge::config::AppConfig;
use kan_edge::coordinator::{Dispatch, TcpServer};
use kan_edge::kan::checkpoint::synthetic_checkpoint_json as kan_variant_json;
use kan_edge::registry::{ModelManifest, ModelRegistry};
use kan_edge::util::json::Value;

mod common;
use common::test_config;

fn tmp_dir(test: &str) -> PathBuf {
    common::tmp_dir("kan_edge_rollout_tests", test)
}

/// Registry over a fresh dir with model "a" published at v1 (favors
/// class 0) and the given rollout config knobs applied.
fn rollout_registry(
    test: &str,
    tune: impl FnOnce(&mut AppConfig),
) -> (PathBuf, AppConfig, Arc<ModelRegistry>) {
    let dir = tmp_dir(test);
    ModelManifest::empty().save(&dir).unwrap();
    let mut cfg = test_config(&dir, "a");
    tune(&mut cfg);
    let registry = ModelRegistry::open(&cfg).unwrap();
    publish_variant(&dir, &registry, &kan_variant_json("a", 0));
    (dir, cfg, registry)
}

fn publish_variant(dir: &Path, registry: &ModelRegistry, ckpt_json: &str) {
    let src = dir.join("incoming.weights.json");
    std::fs::write(&src, ckpt_json).unwrap();
    registry.publish_file(&src, None, None).unwrap();
}

/// A v2 checkpoint that is byte-different from v1 (new digest, so the
/// publish bumps the version and hot-swaps) but numerically identical —
/// a canary that cannot diverge.
fn clean_v2_json() -> String {
    format!("{}\n \n", kan_variant_json("a", 0))
}

fn status_of(client: &mut KanClient, name: &str) -> Value {
    client
        .rollout_status(Some(name))
        .unwrap()
        .field("rollouts")
        .unwrap()
        .field(name)
        .unwrap()
        .clone()
}

fn phase_of(status: &Value) -> String {
    status.get("phase").and_then(|v| v.as_str()).unwrap_or("?").to_string()
}

#[test]
fn split_fraction_is_deterministic_over_live_tcp() {
    // ramp [0.25] parked under an unreachable window: the split runs,
    // the controller never advances
    let (_dir, _cfg, registry) = rollout_registry("split_fraction", |cfg| {
        cfg.rollout.ramp = vec![0.25];
        cfg.rollout.window_ms = 3_600_000;
        cfg.rollout.min_samples = usize::MAX;
    });
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
    let mut client = KanClient::connect(server.addr).unwrap();

    // load v1 live, then hot-swap v2 in (v1 moves to the standby shelf)
    let inf = client.infer_model(Some("a"), &[0.5, 0.5]).unwrap();
    assert_eq!(inf.model, "a@1");
    publish_variant(&_dir, &registry, &clean_v2_json());

    let body = client.rollout_start("a@2", "a@1").unwrap();
    let status = body.field("rollouts").unwrap().field("a").unwrap();
    assert_eq!(phase_of(status), "ramping");
    assert_eq!(
        status.get("fraction").and_then(|v| v.as_f64()).unwrap(),
        0.25
    );

    // the counter-based splitter sends exactly floor(n*f) of the first
    // n default-routed requests to the canary — no randomness
    let (mut canary, mut baseline) = (0u32, 0u32);
    for _ in 0..200 {
        match client.infer_model(Some("a"), &[0.5, 0.5]).unwrap().model.as_str() {
            "a@2" => canary += 1,
            "a@1" => baseline += 1,
            other => panic!("unexpected serving id {other}"),
        }
    }
    assert_eq!((canary, baseline), (50, 150));

    // an explicit version pin bypasses the splitter entirely
    for _ in 0..10 {
        assert_eq!(
            client.infer_model(Some("a@2"), &[0.5, 0.5]).unwrap().model,
            "a@2"
        );
    }

    // a second start while one is running is a clean conflict error
    let err = client.rollout_start("a@2", "a@1").unwrap_err().to_string();
    assert!(err.contains("already in progress"), "{err}");

    client.rollout_abort("a").unwrap();
    server.shutdown();
}

#[test]
fn clean_canary_ramps_and_auto_promotes() {
    let (_dir, _cfg, registry) = rollout_registry("auto_promote", |cfg| {
        cfg.rollout.ramp = vec![0.5];
        cfg.rollout.window_ms = 150;
        cfg.rollout.min_samples = 10;
        cfg.rollout.poll_ms = 10;
        // generous latency gate: identical pipelines, but tiny windows
        // under CI load can see scheduler spikes
        cfg.rollout.max_latency_regression = 1000.0;
    });
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
    let mut client = KanClient::connect(server.addr).unwrap();

    client.infer_model(Some("a"), &[0.5, 0.5]).unwrap();
    publish_variant(&_dir, &registry, &clean_v2_json());
    client.rollout_start("a@2", "a@1").unwrap();

    // drive traffic until the controller walks ramping -> observing ->
    // promoted; every request must succeed throughout
    let deadline = Instant::now() + Duration::from_secs(20);
    let status = loop {
        for _ in 0..30 {
            client.infer_model(Some("a"), &[0.5, 0.5]).unwrap();
        }
        let status = status_of(&mut client, "a");
        if phase_of(&status) == "promoted" {
            break status;
        }
        assert_ne!(phase_of(&status), "rolled_back", "clean canary rolled back: {status}");
        assert!(Instant::now() < deadline, "no promotion before deadline: {status}");
        std::thread::sleep(Duration::from_millis(20));
    };
    assert_eq!(status.get("phase_code").and_then(|v| v.as_i64()), Some(2));

    // the decision history records the whole walk
    let actions: Vec<String> = status
        .get("decisions")
        .and_then(|v| v.as_array())
        .unwrap()
        .iter()
        .map(|d| d.get("action").and_then(|a| a.as_str()).unwrap().to_string())
        .collect();
    assert!(actions.contains(&"start".to_string()), "{actions:?}");
    assert!(actions.contains(&"advance".to_string()), "{actions:?}");
    assert_eq!(actions.last().map(String::as_str), Some("promote"));

    // promoted: the candidate is the manifest default, no override left
    for _ in 0..10 {
        assert_eq!(
            client.infer_model(Some("a"), &[0.5, 0.5]).unwrap().model,
            "a@2"
        );
    }

    // the rollout surfaces as Prometheus series on the same endpoint
    let prom = client.metrics_prom().unwrap();
    assert!(
        prom.contains("kan_edge_rollout_phase_code{model=\"a\"} 2"),
        "missing rollout series:\n{prom}"
    );

    // terminal cleanup released the rollout's pin and the standby shelf
    let ro = registry.rollout_plane().get("a").unwrap();
    assert!(ro.is_terminal());
    client.rollout_clear("a").unwrap();
    assert!(registry.rollout_plane().get("a").is_none());
    server.shutdown();
}

#[test]
fn divergent_canary_rolls_back_without_dropping_requests() {
    let (_dir, _cfg, registry) = rollout_registry("auto_rollback", |cfg| {
        cfg.rollout.ramp = vec![0.5];
        cfg.rollout.window_ms = 120;
        cfg.rollout.min_samples = 5;
        cfg.rollout.poll_ms = 10;
        cfg.rollout.max_flip_rate = 0.01;
        cfg.rollout.max_latency_regression = 1000.0;
    });
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
    let mut client = KanClient::connect(server.addr).unwrap();

    client.infer_model(Some("a"), &[0.5, 0.5]).unwrap();
    // the perturbed canary: favors the other class, so every mirrored
    // row argmax-flips against the baseline
    publish_variant(&_dir, &registry, &kan_variant_json("a", 1));
    client.rollout_start("a@2", "a@1").unwrap();

    // drive continuously through the breach and the repoint: every
    // single request must complete (zero dropped / failed)
    let deadline = Instant::now() + Duration::from_secs(20);
    let status = loop {
        for _ in 0..20 {
            let inf = client.infer_model(Some("a"), &[0.5, 0.5]).unwrap();
            assert_eq!(inf.logits.len(), 2);
        }
        let status = status_of(&mut client, "a");
        if phase_of(&status) == "rolled_back" {
            break status;
        }
        assert!(Instant::now() < deadline, "no rollback before deadline: {status}");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(status.get("phase_code").and_then(|v| v.as_i64()), Some(3));

    // the breach decision carries the gate and the observed value
    let decisions = status.get("decisions").and_then(|v| v.as_array()).unwrap();
    let last = decisions.last().unwrap();
    assert_eq!(last.get("action").and_then(|a| a.as_str()), Some("rollback"));
    let reason = last.get("reason").and_then(|r| r.as_str()).unwrap();
    assert!(
        reason.contains("max_flip_rate") && reason.contains("breached"),
        "{reason}"
    );

    // all default traffic is repointed at the pinned baseline — both
    // named and default-model routes
    for _ in 0..10 {
        assert_eq!(
            client.infer_model(Some("a"), &[0.5, 0.5]).unwrap().model,
            "a@1"
        );
        assert_eq!(client.infer(&[0.5, 0.5]).unwrap().model, "a@1");
    }

    // abort after the fact is a clean "already finished" conflict
    let err = client.rollout_abort("a").unwrap_err().to_string();
    assert!(err.contains("already finished"), "{err}");

    // clearing the record returns default traffic to the manifest-
    // current version (the operator's explicit decision)
    client.rollout_clear("a").unwrap();
    assert_eq!(
        client.infer_model(Some("a"), &[0.5, 0.5]).unwrap().model,
        "a@2"
    );
    server.shutdown();
}

#[test]
fn rollout_survives_hot_reload_poll() {
    let (_dir, _cfg, registry) = rollout_registry("hot_reload", |cfg| {
        cfg.rollout.ramp = vec![0.25];
        cfg.rollout.window_ms = 3_600_000;
        cfg.rollout.min_samples = usize::MAX;
    });
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
    let mut client = KanClient::connect(server.addr).unwrap();

    client.infer_model(Some("a"), &[0.5, 0.5]).unwrap();
    publish_variant(&_dir, &registry, &clean_v2_json());
    client.rollout_start("a@2", "a@1").unwrap();
    for _ in 0..40 {
        client.infer_model(Some("a"), &[0.5, 0.5]).unwrap();
    }
    let before = status_of(&mut client, "a");

    // an unchanged manifest re-read must not disturb the live rollout
    let swapped = registry.poll_reload().unwrap();
    assert!(swapped.is_empty(), "{swapped:?}");
    let after = status_of(&mut client, "a");
    assert_eq!(phase_of(&after), "ramping");
    assert_eq!(
        before.get("fraction").and_then(|v| v.as_f64()),
        after.get("fraction").and_then(|v| v.as_f64()),
    );

    // the splitter still applies after the poll: both versions serve
    let (mut canary, mut baseline) = (0u32, 0u32);
    for _ in 0..40 {
        match client.infer_model(Some("a"), &[0.5, 0.5]).unwrap().model.as_str() {
            "a@2" => canary += 1,
            _ => baseline += 1,
        }
    }
    assert!(canary > 0 && baseline > 0, "canary {canary}, baseline {baseline}");

    client.rollout_abort("a").unwrap();
    server.shutdown();
}
