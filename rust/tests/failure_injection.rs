//! Failure-injection tests: malformed artifacts and wire inputs must
//! produce actionable errors, never panics or silent zeros.

use std::io::Write;

use kan_edge::kan::checkpoint::{Dataset, KanCheckpoint, Manifest, MlpCheckpoint};
#[cfg(feature = "pjrt")]
use kan_edge::runtime::PjrtEngine;
use kan_edge::util::json::Value;

fn write_tmp(name: &str, text: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("kan_edge_failures");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    std::fs::File::create(&path)
        .unwrap()
        .write_all(text.as_bytes())
        .unwrap();
    path
}

#[test]
fn truncated_json_checkpoint() {
    let path = write_tmp("trunc.json", r#"{"name": "x", "kind": "kan", "dims": [1"#);
    let err = KanCheckpoint::load(&path).map(|_| ()).unwrap_err().to_string();
    assert!(err.contains("trunc.json"), "{err}");
}

#[test]
fn wrong_kind_checkpoint() {
    let path = write_tmp(
        "kind.json",
        r#"{"name":"x","kind":"mlp","dims":[2,1],"g":1,"k":1,"n_bits":8,
            "num_params":1,"layers":[]}"#,
    );
    let err = KanCheckpoint::load(&path).map(|_| ()).unwrap_err().to_string();
    assert!(err.contains("kind"), "{err}");
}

#[test]
fn missing_field_names_the_field() {
    let path = write_tmp("nofield.json", r#"{"name": "x", "kind": "kan"}"#);
    let err = KanCheckpoint::load(&path).map(|_| ()).unwrap_err().to_string();
    assert!(err.contains("dims"), "{err}");
}

#[test]
fn mlp_shape_mismatch_detected() {
    let path = write_tmp(
        "mlpbad.json",
        r#"{"name":"m","kind":"mlp","dims":[2,2],"num_params":6,
            "layers":[{"din":2,"dout":2,"w":[1.0,2.0,3.0],"b":[0.0,0.0]}]}"#,
    );
    let err = MlpCheckpoint::load(&path).map(|_| ()).unwrap_err().to_string();
    assert!(err.contains("shape") || err.contains("layer"), "{err}");
}

#[test]
fn dataset_inconsistent_lengths() {
    let dir = std::env::temp_dir().join("kan_edge_failures_ds");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("dataset.json"),
        r#"{"test_x":[1.0,2.0,3.0],"test_y":[0],"calib_x":[],"calib_y":[],
            "num_features":2,"num_classes":3}"#,
    )
    .unwrap();
    let err = Dataset::load(&dir).map(|_| ()).unwrap_err().to_string();
    assert!(err.contains("inconsistent"), "{err}");
}

#[test]
fn manifest_missing_dir() {
    let err = Manifest::load("/no/such/dir").map(|_| ()).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "{err}");
}

#[cfg(all(feature = "pjrt", feature = "xla"))]
#[test]
fn corrupt_hlo_text_fails_to_compile() {
    let path = write_tmp("bad.hlo.txt", "HloModule garbage\n\nthis is not hlo\n");
    let engine = PjrtEngine::cpu().unwrap();
    assert!(engine.load_hlo(&path, 1, 17, 14).is_err());
}

#[cfg(not(all(feature = "pjrt", feature = "xla")))]
#[test]
fn pjrt_stub_errors_mention_the_feature() {
    // built without the xla dependency: the stub engine must fail loudly
    // and actionably, never pretend to run
    let err = kan_edge::runtime::PjrtEngine::cpu()
        .map(|_| ())
        .unwrap_err()
        .to_string();
    assert!(err.contains("pjrt"), "{err}");
}

#[cfg(all(feature = "pjrt", feature = "xla"))]
#[test]
fn pjrt_run_rejects_wrong_input_len() {
    // use a real artifact if available
    let dir = "../artifacts";
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = PjrtEngine::cpu().unwrap();
    let exe = engine
        .load_hlo(format!("{dir}/kan1.b1.hlo.txt"), 1, 17, 14)
        .unwrap();
    let err = exe.run(&vec![0.0; 16]).map(|_| ()).unwrap_err().to_string();
    assert!(err.contains("17"), "{err}");
}

#[cfg(all(feature = "pjrt", feature = "xla"))]
#[test]
fn pjrt_padding_of_short_batches_is_correct() {
    // PjrtBackend pads chunks to the compiled batch; padded rows must not
    // leak into live outputs
    let dir = "../artifacts";
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use kan_edge::coordinator::ExecutionSession;
    use kan_edge::coordinator::PjrtSession;
    let be = PjrtSession::spawn(
        format!("{dir}/kan1.b32.hlo.txt").into(),
        32,
        17,
        14,
        "kan1".into(),
    )
    .unwrap();
    let row: Vec<f32> = (0..17).map(|i| (i as f32) * 0.05 - 0.4).collect();
    // 1-row batch (31 padded) vs the same row inside a 3-row batch
    let a = be.infer_logits(vec![row.clone()]).unwrap();
    let b = be
        .infer_logits(vec![vec![0.3; 17], row.clone(), vec![-0.2; 17]])
        .unwrap();
    for (x, y) in a[0].iter().zip(&b[1]) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

#[test]
fn json_parser_rejects_pathological_inputs() {
    for bad in [
        "",
        "{",
        "}",
        "[1,",
        "\"unterminated",
        "nul",
        "+5",
        "01x",
        "{\"a\" 1}",
        "[1 2]",
        "\"\\u12\"",
        "\"\\ud800\"", // unpaired surrogate
    ] {
        assert!(Value::parse(bad).is_err(), "accepted: {bad:?}");
    }
}

#[test]
fn deep_json_nesting_does_not_overflow() {
    // 1000 nested arrays: recursive parser must handle it (or error),
    // never crash the process with a stack overflow at sane depths
    let text = format!("{}1{}", "[".repeat(1000), "]".repeat(1000));
    let v = Value::parse(&text);
    assert!(v.is_ok());
}
