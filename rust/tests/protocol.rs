//! Serving-protocol integration tests: v1 backward compatibility,
//! v1/v2 auto-detection on one port, framed v2 with pipelined
//! out-of-order completion, batch submit feeding the dynamic batcher,
//! the control plane over a live registry, request-size bounds, and
//! the typed `KanClient` end-to-end. Fully offline (synthetic KAN
//! checkpoints, digital backend).

#![allow(clippy::field_reassign_with_default)]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use kan_edge::client::KanClient;
use kan_edge::coordinator::protocol::{read_frame, write_frame, FrameRead, MAGIC};
use kan_edge::coordinator::{
    ClientId, Dispatch, RouteSpec, RowOutput, TcpLimits, TcpServer,
};
use kan_edge::error::Result;
use kan_edge::kan::checkpoint::synthetic_checkpoint_json as kan_variant_json;
use kan_edge::registry::ModelRegistry;
use kan_edge::util::json::Value;

// ---- fixtures (shared with tests/registry.rs via tests/common) ------------

mod common;
use common::{test_config, write_manifest_v2};

fn tmp_dir(test: &str) -> PathBuf {
    common::tmp_dir("kan_edge_protocol_tests", test)
}

/// Registry server over two variants: "a" favors class 0, "b" class 1.
fn registry_server(test: &str) -> (Arc<ModelRegistry>, TcpServer) {
    let dir = tmp_dir(test);
    std::fs::write(dir.join("a.weights.json"), kan_variant_json("a", 0)).unwrap();
    std::fs::write(dir.join("b.weights.json"), kan_variant_json("b", 1)).unwrap();
    write_manifest_v2(&dir, &[("a", "a.weights.json", 1), ("b", "b.weights.json", 1)]);
    let registry = ModelRegistry::open(&test_config(&dir, "a")).unwrap();
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target).unwrap();
    (registry, server)
}

/// One v1 JSON-lines request over an open connection.
fn v1_request(
    conn: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    body: &str,
) -> Value {
    conn.write_all(body.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Value::parse(line.trim()).unwrap()
}

/// Raw v2 helpers for tests that drive frames by hand.
fn v2_connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(&MAGIC).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

fn v2_send(conn: &mut TcpStream, json: &str) {
    write_frame(conn, json.as_bytes()).unwrap();
}

fn v2_recv(reader: &mut BufReader<TcpStream>) -> Value {
    match read_frame(reader, 1 << 20).unwrap() {
        FrameRead::Frame(p) => Value::parse(std::str::from_utf8(&p).unwrap()).unwrap(),
        other => panic!("expected frame, got {other:?}"),
    }
}

// ---- v1 backward compatibility --------------------------------------------

#[test]
fn v1_clients_work_unchanged_against_the_new_server() {
    let (_registry, server) = registry_server("v1_compat");
    // exactly what a pre-v2 client script does: JSON lines, in-order
    // replies, optional "model" routing, error replies for garbage
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    let v = v1_request(&mut conn, &mut reader, r#"{"features": [0.5, 0.5]}"#);
    assert_eq!(v.get("class").unwrap().as_i64().unwrap(), 0);
    assert_eq!(v.get("model").unwrap().as_str().unwrap(), "a@1");

    let v = v1_request(
        &mut conn,
        &mut reader,
        r#"{"model": "b", "features": [0.5, 0.5]}"#,
    );
    assert_eq!(v.get("class").unwrap().as_i64().unwrap(), 1);
    assert_eq!(v.get("model").unwrap().as_str().unwrap(), "b@1");

    // garbage gets a structured error and the connection stays usable
    let v = v1_request(&mut conn, &mut reader, "not json at all");
    assert!(v.get("error").is_some());
    let v = v1_request(&mut conn, &mut reader, r#"{"features": [0.5, 0.5]}"#);
    assert_eq!(v.get("class").unwrap().as_i64().unwrap(), 0);

    server.shutdown();
}

#[test]
fn v1_oversized_line_gets_error_then_connection_drops() {
    let (_registry, server) = {
        let dir = tmp_dir("v1_oversized");
        std::fs::write(dir.join("a.weights.json"), kan_variant_json("a", 0)).unwrap();
        write_manifest_v2(&dir, &[("a", "a.weights.json", 1)]);
        let registry = ModelRegistry::open(&test_config(&dir, "a")).unwrap();
        let target: Arc<dyn Dispatch> = registry.clone();
        let limits = TcpLimits { max_request_bytes: 256, max_in_flight: 4 };
        let server = TcpServer::spawn_with_limits("127.0.0.1:0", target, limits).unwrap();
        (registry, server)
    };
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    // a 4 KiB line against a 256-byte limit
    let mut big = String::from("{\"features\": [");
    big.push_str(&vec!["0.5"; 1024].join(","));
    big.push_str("]}\n");
    conn.write_all(big.as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Value::parse(line.trim()).unwrap();
    assert!(v.get("error").unwrap().as_str().unwrap().contains("too large"));
    assert_eq!(v.get("code").unwrap().as_str().unwrap(), "too_large");
    // only this connection dropped (clean EOF, or RST if the server
    // closed with part of the oversized line still unread)...
    let mut end = String::new();
    assert_eq!(reader.read_line(&mut end).unwrap_or(0), 0, "connection not closed");
    // ...the server keeps serving new ones
    let mut conn2 = TcpStream::connect(server.addr).unwrap();
    let mut reader2 = BufReader::new(conn2.try_clone().unwrap());
    let v = v1_request(&mut conn2, &mut reader2, r#"{"features": [0.5, 0.5]}"#);
    assert_eq!(v.get("class").unwrap().as_i64().unwrap(), 0);
    server.shutdown();
}

// ---- v2 framing and control plane -----------------------------------------

#[test]
fn v2_raw_hello_garbage_frame_and_ping() {
    let (_registry, server) = registry_server("v2_raw");
    let (mut conn, mut reader) = v2_connect(server.addr);

    v2_send(&mut conn, r#"{"id": 1, "op": "hello", "client": "raw"}"#);
    let v = v2_recv(&mut reader);
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "hello");
    assert_eq!(v.get("protocol").unwrap().as_i64().unwrap(), 2);
    assert!(v.get("max_frame").unwrap().as_i64().unwrap() > 0);

    // a garbage frame gets a structured error; framing stays intact
    v2_send(&mut conn, "this is not json");
    let v = v2_recv(&mut reader);
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "error");
    assert_eq!(v.get("code").unwrap().as_str().unwrap(), "bad_request");

    // ...so the connection is still usable
    v2_send(&mut conn, r#"{"id": 2, "op": "ping"}"#);
    let v = v2_recv(&mut reader);
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "pong");
    assert_eq!(v.get("id").unwrap().as_i64().unwrap(), 2);

    // unknown op is typed unsupported
    v2_send(&mut conn, r#"{"id": 3, "op": "frobnicate"}"#);
    let v = v2_recv(&mut reader);
    assert_eq!(v.get("code").unwrap().as_str().unwrap(), "unsupported");
    assert_eq!(v.get("id").unwrap().as_i64().unwrap(), 3);

    server.shutdown();
}

#[test]
fn v2_oversized_frame_gets_error_then_connection_drops() {
    let dir = tmp_dir("v2_oversized");
    std::fs::write(dir.join("a.weights.json"), kan_variant_json("a", 0)).unwrap();
    write_manifest_v2(&dir, &[("a", "a.weights.json", 1)]);
    let registry = ModelRegistry::open(&test_config(&dir, "a")).unwrap();
    let target: Arc<dyn Dispatch> = registry.clone();
    let limits = TcpLimits { max_request_bytes: 256, max_in_flight: 4 };
    let server = TcpServer::spawn_with_limits("127.0.0.1:0", target, limits).unwrap();

    let (mut conn, mut reader) = v2_connect(server.addr);
    // header declaring a 1 MiB payload against a 256-byte limit
    conn.write_all(&(1u32 << 20).to_be_bytes()).unwrap();
    let v = v2_recv(&mut reader);
    assert_eq!(v.get("op").unwrap().as_str().unwrap(), "error");
    assert_eq!(v.get("code").unwrap().as_str().unwrap(), "too_large");
    assert!(v.get("id").unwrap() == &Value::Null);
    let mut rest = Vec::new();
    assert_eq!(conn.try_clone().unwrap().read_to_end(&mut rest).unwrap_or(0), 0);
    server.shutdown();
}

#[test]
fn v2_control_plane_exposes_registry() {
    let (_registry, server) = registry_server("v2_control");
    let mut client = KanClient::connect(server.addr).unwrap();
    assert_eq!(client.server_info().protocol, 2);
    assert!(client.server_info().server.starts_with("kan-edge/"));
    client.ping().unwrap();

    let models = client.list_models().unwrap();
    let names: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
    assert_eq!(names, ["a", "b"]);
    assert!(models.iter().all(|m| !m.live), "nothing loaded yet");

    let info = client.model_info("a").unwrap();
    assert_eq!(info.version, 1);
    assert_eq!(info.dims, vec![2, 2]);
    assert!(info.digest.is_some());
    // the same spec grammar as inference routing: pinned version works,
    // a stale pin does not
    assert_eq!(client.model_info("a@1").unwrap().version, 1);
    assert!(client.model_info("a@9").is_err());
    let err = client.model_info("nope").unwrap_err();
    assert!(err.to_string().contains("not found"), "{err}");

    let (status, live) = client.health().unwrap();
    assert_eq!(status, "ok");
    assert_eq!(live, 0);

    // first inference loads the pipeline; control plane reflects it
    let out = client.infer_model(Some("a"), &[0.5, 0.5]).unwrap();
    assert_eq!(out.class, 0);
    assert_eq!(out.model, "a@1");
    let (_, live) = client.health().unwrap();
    assert_eq!(live, 1);
    let models = client.list_models().unwrap();
    assert!(models.iter().any(|m| m.name == "a" && m.live));

    server.shutdown();
}

// ---- pipelining ------------------------------------------------------------

/// Dispatch whose per-request latency is controlled by the second
/// feature (milliseconds); the first feature is echoed back in the
/// logits so responses correlate to requests.
struct SleepyEcho;

impl Dispatch for SleepyEcho {
    fn dispatch(
        &self,
        _client: ClientId,
        _route: &RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, RowOutput)> {
        let delay_ms = features.get(1).copied().unwrap_or(0.0);
        if delay_ms > 0.0 {
            std::thread::sleep(Duration::from_millis(delay_ms as u64));
        }
        let x = features.first().copied().unwrap_or(0.0);
        Ok(("echo@1".into(), vec![x, -x].into()))
    }
}

#[test]
fn v2_pipelines_32_requests_out_of_order_on_one_connection() {
    let server = TcpServer::spawn("127.0.0.1:0", Arc::new(SleepyEcho)).unwrap();
    let mut client = KanClient::connect(server.addr).unwrap();

    // 40 pipelined requests on one connection: the first is slow
    // (300 ms), the rest are instant — completion order must not be
    // submission order, and every response must correlate by id
    const N: usize = 40;
    let mut expect = std::collections::BTreeMap::new();
    for i in 0..N {
        let delay = if i == 0 { 300.0f32 } else { 0.0 };
        let id = client.submit(None, &[i as f32, delay]).unwrap();
        expect.insert(id, i as f32);
    }
    let slow_id = *expect.keys().next().unwrap();
    let mut arrival_of_slow = None;
    for arrival in 0..N {
        let (id, outcome) = client.poll().unwrap();
        let out = outcome.unwrap();
        let want = expect.remove(&id).expect("unknown or duplicate id");
        assert_eq!(out.logits[0], want, "id {id} correlated to wrong payload");
        assert_eq!(out.model, "echo@1");
        if id == slow_id {
            arrival_of_slow = Some(arrival);
        }
    }
    assert!(expect.is_empty(), "missing responses: {expect:?}");
    let pos = arrival_of_slow.expect("slow request never completed");
    assert!(
        pos >= N / 2,
        "expected the slow request to finish after the fast ones, \
         but it arrived at position {pos}/{N}"
    );

    // the transport saw real pipelining depth
    let hwm = server.wire.to_value();
    assert!(
        hwm.get("in_flight_hwm").unwrap().as_i64().unwrap() > 1,
        "no pipelining observed: {hwm}"
    );
    server.shutdown();
}

/// Dispatch that panics on a negative first feature, echoes otherwise.
struct PanicOnNegative;

impl Dispatch for PanicOnNegative {
    fn dispatch(
        &self,
        _client: ClientId,
        _route: &RouteSpec,
        features: Vec<f32>,
    ) -> Result<(String, RowOutput)> {
        let x = features.first().copied().unwrap_or(0.0);
        assert!(x >= 0.0, "injected dispatch panic");
        Ok(("echo@1".into(), vec![x, -x].into()))
    }
}

#[test]
fn v2_panicking_dispatch_answers_internal_error_not_silence() {
    let server = TcpServer::spawn("127.0.0.1:0", Arc::new(PanicOnNegative)).unwrap();
    let mut client = KanClient::connect(server.addr).unwrap();
    let bad = client.submit(None, &[-1.0, 0.0]).unwrap();
    let good = client.submit(None, &[2.0, 0.0]).unwrap();
    let mut seen = std::collections::BTreeMap::new();
    for _ in 0..2 {
        let (id, outcome) = client.poll().unwrap();
        seen.insert(id, outcome);
    }
    // the panicking dispatch still answered (typed internal error), and
    // the connection survived to serve the other request
    let err = seen.remove(&bad).unwrap().unwrap_err();
    assert!(err.to_string().contains("internal"), "{err}");
    assert_eq!(seen.remove(&good).unwrap().unwrap().logits[0], 2.0);
    client.ping().unwrap();
    server.shutdown();
}

#[test]
fn v2_in_flight_cap_backpressures_without_breaking_correctness() {
    let limits = TcpLimits { max_request_bytes: 1 << 20, max_in_flight: 4 };
    let server =
        TcpServer::spawn_with_limits("127.0.0.1:0", Arc::new(SleepyEcho), limits)
            .unwrap();
    let mut client = KanClient::connect(server.addr).unwrap();
    assert_eq!(client.server_info().max_in_flight, 4);
    // submit more than the cap; the server reader blocks as needed and
    // everything still completes exactly once
    let mut pending = std::collections::BTreeSet::new();
    for i in 0..12 {
        pending.insert(client.submit(None, &[i as f32, 5.0]).unwrap());
    }
    for _ in 0..12 {
        let (id, outcome) = client.poll().unwrap();
        outcome.unwrap();
        assert!(pending.remove(&id), "duplicate completion for {id}");
    }
    assert!(pending.is_empty());
    // a surplus poll fails fast instead of blocking on a response the
    // server will never send
    let err = client.poll().unwrap_err();
    assert!(err.to_string().contains("no requests in flight"), "{err}");
    let hwm = server.wire.to_value();
    let observed = hwm.get("in_flight_hwm").unwrap().as_i64().unwrap();
    assert!(observed <= 4, "cap violated: {observed}");
    server.shutdown();
}

// ---- batch submit -----------------------------------------------------------

#[test]
fn v2_batch_submit_feeds_the_batcher_whole() {
    let (_registry, server) = registry_server("v2_batch");
    let mut client = KanClient::connect(server.addr).unwrap();

    let rows: Vec<Vec<f32>> = (0..64).map(|_| vec![0.5, 0.5]).collect();
    let (model, results) = client.infer_batch(Some("a"), rows.clone()).unwrap();
    assert_eq!(model, "a@1");
    assert_eq!(results.len(), 64);
    assert!(results.iter().all(|r| r.class == 0));

    // the server-side batcher must have seen multi-row batches from
    // this single connection (the whole point of the verb)
    let metrics = client.metrics().unwrap();
    let report = metrics.field("models").unwrap().get("a@1").unwrap();
    assert_eq!(report.get("requests").unwrap().as_i64().unwrap(), 64);
    let mean_batch = report.get("mean_batch").unwrap().as_f64().unwrap();
    assert!(
        mean_batch > 1.5,
        "batch submit degenerated to singletons (mean {mean_batch})"
    );
    let wire = metrics.field("wire").unwrap();
    assert!(wire.get("v2_requests").unwrap().as_i64().unwrap() >= 1);
    assert!(wire.get("v2_rows").unwrap().as_i64().unwrap() >= 64);

    // batch errors are typed: unknown model
    let err = client.infer_batch(Some("nope"), rows).unwrap_err();
    assert!(err.to_string().contains("not_found"), "{err}");
    server.shutdown();
}

// ---- typed client round-trips ----------------------------------------------

#[test]
fn kan_client_roundtrips_against_live_server() {
    let (_registry, server) = registry_server("client_roundtrip");
    let mut client = KanClient::connect(server.addr).unwrap();

    // default model (config default "a")
    let out = client.infer(&[0.5, 0.5]).unwrap();
    assert_eq!((out.class, out.model.as_str()), (0, "a@1"));
    // routed + pinned
    let out = client.infer_model(Some("b"), &[0.5, 0.5]).unwrap();
    assert_eq!((out.class, out.model.as_str()), (1, "b@1"));
    let out = client.infer_model(Some("b@1"), &[0.5, 0.5]).unwrap();
    assert_eq!(out.model, "b@1");
    // stale pin is a typed error
    let err = client.infer_model(Some("b@9"), &[0.5, 0.5]).unwrap_err();
    assert!(err.to_string().contains("not_found"), "{err}");
    // shape errors from the backend surface as bad_request
    let err = client.infer_model(Some("a"), &[0.5]).unwrap_err();
    assert!(err.to_string().contains("bad_request"), "{err}");
    // mixed traffic on the same connection still correlates
    client.ping().unwrap();
    let out = client.infer(&[0.5, 0.5]).unwrap();
    assert_eq!(out.class, 0);

    // v1 and v2 clients coexist on the port
    let mut conn = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let v = v1_request(&mut conn, &mut reader, r#"{"features": [0.5, 0.5]}"#);
    assert_eq!(v.get("model").unwrap().as_str().unwrap(), "a@1");

    let metrics = client.metrics().unwrap();
    let wire = metrics.field("wire").unwrap();
    assert!(wire.get("v1_requests").unwrap().as_i64().unwrap() >= 1);
    assert!(wire.get("v2_requests").unwrap().as_i64().unwrap() >= 4);
    assert!(wire.get("connections_active").unwrap().as_i64().unwrap() >= 2);

    server.shutdown();
}
