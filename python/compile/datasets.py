"""Synthetic knot-theory surrogate dataset.

The paper evaluates on the knot-theory task of Davies et al. (Nature 2021):
predict a knot's *signature* (14 even-valued classes) from 17 real-valued
knot invariants. That dataset, in the shape the paper used, is not publicly
redistributable, so we synthesize a surrogate that preserves what matters for
the reproduction (DESIGN.md section 4):

* arity: 17 input features, 14 classes;
* structure: the label is a *sparse additive* functional of the inputs --
  mirroring the finding (in both Davies et al. and the original KAN paper)
  that signature is dominated by a few invariants combined smoothly. This is
  precisely the function class a 17x1x14 KAN is well-specified for, while a
  190k-parameter MLP has no such inductive bias and overfits the small
  training set -- reproducing the paper's accuracy ordering from structure
  rather than curve-fitting;
* distribution: classes are *bands* of the additive score (clip(round(s/d)))
  so the class histogram is peaked around the center -- mirroring the real
  signature distribution, which concentrates near 0;
* difficulty: label noise keeps test accuracy in the paper's 75-90% band
  (measured Bayes ceiling of the default configuration: ~92%).

Deterministic given the seed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

NUM_FEATURES = 17
NUM_CLASSES = 14
# invariants that actually drive the signature (longitudinal translation,
# meridional distance etc. in Davies et al.; indices here are arbitrary)
ACTIVE_DIMS = (0, 2, 5, 9, 13, 16)


@dataclasses.dataclass
class Splits:
    train_x: np.ndarray
    train_y: np.ndarray
    val_x: np.ndarray
    val_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray


def _additive_truth(x: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Smooth sparse-additive score s(x) = sum_k g_k(x_k) over ACTIVE_DIMS."""
    coefs = rng.uniform(0.7, 1.3, size=len(ACTIVE_DIMS))
    phases = rng.uniform(0, 2 * np.pi, size=len(ACTIVE_DIMS))
    s = np.zeros(x.shape[0], dtype=np.float64)
    for idx, (d, a, p) in enumerate(zip(ACTIVE_DIMS, coefs, phases)):
        xd = x[:, d]
        if idx % 3 == 0:
            s += a * np.sin(2.0 * xd + p)
        elif idx % 3 == 1:
            s += a * np.tanh(2.5 * xd)
        else:
            s += a * (xd**2 - 0.5)
    return s


def generate(
    n: int = 6000,
    seed: int = 7,
    noise: float = 0.05,
    band_div: float = 2.2,
    train_frac: float = 2 / 3,
    val_frac: float = 1 / 6,
) -> Splits:
    """Generate the surrogate dataset and split train/val/test."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, size=(n, NUM_FEATURES)).astype(np.float32)
    s = _additive_truth(x, rng)
    s_noisy = s + rng.normal(0.0, noise * np.std(s), size=n)
    # class = signed band of the score (like the even-valued signature bands
    # of the real task): peaked distribution with rare extreme classes
    delta = np.std(s) / band_div
    y = (np.clip(np.round(s_noisy / delta), -7, 6) + 7).astype(np.int32)

    n_train = int(n * train_frac)
    n_val = int(n * val_frac)
    return Splits(
        train_x=x[:n_train],
        train_y=y[:n_train],
        val_x=x[n_train : n_train + n_val],
        val_y=y[n_train : n_train + n_val],
        test_x=x[n_train + n_val :],
        test_y=y[n_train + n_val :],
    )
