"""Pallas kernels for the quantized KAN layer (the hot spot, L1).

Hardware adaptation (DESIGN.md section 1): the paper's circuit evaluates
B(X) with an SH-LUT + decoder/MUX network and does the ci' MAC on an RRAM
crossbar. On a TPU-shaped target the same decomposition becomes:

* SH-LUT              -> small f32 table resident in VMEM
* (n-D)-bit decoder   -> vectorized ``x_q >> LD``
* D-bit decoder       -> vectorized ``x_q & (2**LD - 1)``
* TG-MUX/DEMUX routing-> one-hot compare + tiny matmul (LUT row gather) and
                         iota-compare scatter of the K+1 active basis values
                         into a dense (G+K) activation row
* RRAM crossbar MAC   -> one [B, Din*(G+K)] @ [Din*(G+K), Dout] matmul that
                         maps onto the MXU systolic array

Gathers are rewritten as one-hot matmuls on purpose: scatter/gather is
hostile to the MXU, dense matmul is what it is built for -- the same
cheap-routing / wide-MAC trade the paper makes in silicon.

Kernels are lowered with ``interpret=True``: the CPU PJRT client cannot run
Mosaic custom-calls, and the interpret path produces plain HLO that the rust
runtime executes. Correctness vs ``ref.py`` is enforced by pytest+hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.quant import AspQuantSpec


def _pick_block(batch: int, want: int = 128) -> int:
    """Largest divisor of ``batch`` that is <= ``want`` (grid must tile exactly)."""
    b = min(batch, want)
    while batch % b != 0:
        b -= 1
    return b


def _spline_body(xq, lut, coeff, spec: AspQuantSpec):
    """Shared kernel body: quantized codes -> spline MAC output.

    xq:    i32 [B, Din]            input codes in [0, R-1]
    lut:   f32 [2**LD, K+1]        shared (full) LUT
    coeff: f32 [Din*(G+K), Dout]   ci' laid out for a single wide matmul
    """
    lvl = spec.levels_per_interval
    nb = spec.num_basis
    b, din = xq.shape

    j = jax.lax.shift_right_logical(xq, spec.ld)  # global: interval index
    l = jax.lax.bitwise_and(xq, lvl - 1)  # local: SH-LUT row

    # LUT row gather as one-hot matmul: [B*Din, lvl] @ [lvl, K+1]
    onehot = (l.reshape(-1, 1) == jax.lax.iota(jnp.int32, lvl)[None, :]).astype(
        jnp.float32
    )
    vals = onehot @ lut  # [B*Din, K+1]
    vals = vals.reshape(b, din, spec.k + 1)

    # Scatter the K+1 active basis values into a dense (G+K) activation row:
    # act[b, i, j+t] = vals[b, i, t]. K is tiny and static, so unroll over t.
    giota = jax.lax.iota(jnp.int32, nb)[None, None, :]  # [1, 1, G+K]
    act = jnp.zeros((b, din, nb), jnp.float32)
    for t in range(spec.k + 1):
        mask = (giota == (j + t)[..., None]).astype(jnp.float32)
        act = act + vals[..., t][..., None] * mask

    # The wide MAC: this is the crossbar / MXU part.
    return act.reshape(b, din * nb) @ coeff


def _spline_mac_kernel(xq_ref, lut_ref, coeff_ref, o_ref, *, spec: AspQuantSpec):
    o_ref[...] = _spline_body(xq_ref[...], lut_ref[...], coeff_ref[...], spec)


def spline_mac(xq, lut, coeff, spec: AspQuantSpec, *, block: int = 128):
    """Quantized spline MAC: y[b,o] = sum_i sum_t LUT[l,t] * ci'[i, j+t, o].

    xq:    i32 [B, Din], lut: f32 [2**LD, K+1],
    coeff: f32 [Din, G+K, Dout] (reshaped internally). Returns f32 [B, Dout].
    """
    batch, din = xq.shape
    dout = coeff.shape[-1]
    nb = spec.num_basis
    coeff2d = coeff.reshape(din * nb, dout)
    blk = _pick_block(batch, block)
    grid = (batch // blk,)
    return pl.pallas_call(
        functools.partial(_spline_mac_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, din), lambda i: (i, 0)),
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
            pl.BlockSpec(coeff2d.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, dout), jnp.float32),
        interpret=True,
    )(xq, lut, coeff2d)


def _kan_layer_kernel(xq_ref, lut_ref, coeff_ref, wb_ref, o_ref, *, spec: AspQuantSpec):
    """Fused KAN layer: residual ReLU path + spline MAC in one kernel."""
    xq = xq_ref[...]
    spline = _spline_body(xq, lut_ref[...], coeff_ref[...], spec)
    # Residual b(x) = ReLU(x) on the dequantized value (w_b path of eq. 1).
    x = spec.lo + xq.astype(jnp.float32) * spec.step
    o_ref[...] = spline + jnp.maximum(x, 0.0) @ wb_ref[...]


def kan_layer(xq, lut, coeff, wb, spec: AspQuantSpec, *, block: int = 128):
    """Fused quantized KAN layer.

    xq: i32 [B, Din]; lut: f32 [2**LD, K+1]; coeff: f32 [Din, G+K, Dout];
    wb: f32 [Din, Dout]. Returns f32 [B, Dout] (pre-requantization).
    """
    batch, din = xq.shape
    dout = coeff.shape[-1]
    coeff2d = coeff.reshape(din * spec.num_basis, dout)
    blk = _pick_block(batch, block)
    grid = (batch // blk,)
    return pl.pallas_call(
        functools.partial(_kan_layer_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, din), lambda i: (i, 0)),
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
            pl.BlockSpec(coeff2d.shape, lambda i: (0, 0)),
            pl.BlockSpec(wb.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, dout), jnp.float32),
        interpret=True,
    )(xq, lut, coeff2d, wb)
