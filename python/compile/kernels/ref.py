"""Pure-jnp correctness oracle for the KAN spline kernels.

Evaluates the B-spline basis with the textbook Cox-de Boor recursion on a
*uniform extended* knot grid -- the construction the original KAN paper uses
and the one that makes every basis function a translate of the cardinal
B-spline (the property ASP-KAN-HAQ exploits for LUT sharing).

Everything here is the slow-but-obviously-correct path; the Pallas kernel in
`kan_spline.py` must match it bit-for-bit up to float tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp


def cardinal_bspline(s, k: int):
    """Cardinal B-spline C_k(s) of degree ``k`` with support [0, k+1].

    Cox-de Boor on integer knots 0,1,...,k+1. Vectorized over ``s``.
    """
    s = jnp.asarray(s, jnp.float32)
    # degree-0 pieces: N_j^0(s) = 1 on [j, j+1), j = 0..k
    js = jnp.arange(k + 1, dtype=jnp.float32)
    n = jnp.where((s[..., None] >= js) & (s[..., None] < js + 1.0), 1.0, 0.0)
    for d in range(1, k + 1):
        # N_j^d(s) = (s-j)/d * N_j^{d-1} + (j+d+1-s)/d * N_{j+1}^{d-1}
        js = jnp.arange(k + 1 - d, dtype=jnp.float32)
        left = (s[..., None] - js) / d * n[..., : k + 1 - d]
        right = (js + d + 1.0 - s[..., None]) / d * n[..., 1 : k + 2 - d]
        n = left + right
    return n[..., 0]


def basis_functions(z, g: int, k: int):
    """All ``g + k`` basis values at grid coordinate ``z`` in [0, g].

    ``z = (x - lo) / h`` where h is the knot spacing. Basis ``i`` is the
    cardinal spline translated so its support covers grid intervals
    ``[i-k, i]``: B_i(z) = C_k(z - i + k).

    Returns shape ``z.shape + (g + k,)``.
    """
    z = jnp.asarray(z, jnp.float32)
    i = jnp.arange(g + k, dtype=jnp.float32)
    return cardinal_bspline(z[..., None] - i + k, k)


def spline_mac_ref(z, coeff, g: int, k: int):
    """Reference spline MAC: y[b, o] = sum_i sum_j B_j(z[b,i]) * coeff[i,j,o].

    z:     f32 [B, Din]   grid coordinates in [0, g]
    coeff: f32 [Din, g+k, Dout]
    """
    basis = basis_functions(z, g, k)  # [B, Din, g+k]
    return jnp.einsum("big,igo->bo", basis, coeff)


def kan_layer_ref(x, coeff, wb, lo, hi, g: int, k: int):
    """Reference (float, un-quantized) KAN layer.

    phi_{i->o}(x_i) = wb[i,o] * relu(x_i) + sum_j coeff[i,j,o] * B_j(x_i)
    y_o = sum_i phi_{i->o}(x_i)

    Inputs outside [lo, hi] are clamped to the grid (hardware behaviour).
    """
    h = (hi - lo) / g
    z = jnp.clip((x - lo) / h, 0.0, float(g))
    return jnp.maximum(x, 0.0) @ wb + spline_mac_ref(z, coeff, g, k)
