"""L2: JAX model definitions -- KAN (float training + quantized inference
graphs) and the traditional-MLP baseline of Fig 13.

The float forward is the differentiable training path (exact Cox-de Boor
splines from ``kernels/ref.py``). The quantized forward is the *inference*
graph that gets AOT-lowered to HLO text for the rust runtime: it routes every
layer through the fused Pallas kernel (``kernels/kan_spline.py``) and
requantizes activations between layers, mirroring the hardware dataflow of
DESIGN.md section 6.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from compile import quant
from compile.kernels import kan_spline, ref


@dataclasses.dataclass(frozen=True)
class KanConfig:
    """Architecture of a KAN: ``dims`` = [in, hidden..., out], grid G, degree K."""

    dims: tuple
    g: int
    k: int = 3
    n_bits: int = 8

    @property
    def num_layers(self) -> int:
        return len(self.dims) - 1

    @property
    def num_edges(self) -> int:
        return sum(a * b for a, b in zip(self.dims[:-1], self.dims[1:]))

    @property
    def num_params(self) -> int:
        """Paper's parameter count: (G + K + 1) per edge (ci' plus w_b)."""
        return self.num_edges * (self.g + self.k + 1)


def init_kan(cfg: KanConfig, key) -> list:
    """One dict per layer: coeff [Din, G+K, Dout], wb [Din, Dout]."""
    params = []
    nb = cfg.g + cfg.k
    for din, dout in zip(cfg.dims[:-1], cfg.dims[1:]):
        key, k1, k2 = jax.random.split(key, 3)
        params.append(
            {
                "coeff": 0.1 * jax.random.normal(k1, (din, nb, dout), jnp.float32),
                "wb": jax.random.normal(k2, (din, dout), jnp.float32)
                * jnp.sqrt(2.0 / din),
            }
        )
    return params


def kan_forward(params: Sequence[dict], x, ranges: Sequence[tuple], cfg: KanConfig):
    """Float (training) forward. ``ranges[i] = (lo, hi)`` is layer i's grid span."""
    h = x
    for p, (lo, hi) in zip(params, ranges):
        h = ref.kan_layer_ref(h, p["coeff"], p["wb"], lo, hi, cfg.g, cfg.k)
    return h


def calibrate_ranges(params, x, cfg: KanConfig, margin: float = 0.05):
    """Run the float forward, record each layer's input span (+margin).

    The spans become the knot-grid ranges of the quantized model; the margin
    absorbs activation drift between calibration and test data.
    """
    ranges = []
    h = x
    for p in params:
        lo = float(jnp.min(h))
        hi = float(jnp.max(h))
        pad = margin * (hi - lo) + 1e-6
        ranges.append((lo - pad, hi + pad))
        h = ref.kan_layer_ref(h, p["coeff"], p["wb"], lo - pad, hi + pad, cfg.g, cfg.k)
    return ranges


@dataclasses.dataclass
class QuantizedKan:
    """Post-training-quantized KAN: everything the hardware needs.

    Per layer: an ASP spec (grid geometry), the quantized SH-LUT, int8 ci'
    with scale, and the float residual weights w_b (the w_b*ReLU path is a
    standard crossbar MAC; it is quantized separately on the rust side).
    """

    cfg: KanConfig
    specs: list  # AspQuantSpec per layer
    sh_luts: list  # int64 [2**(LD-1)+1, K+1] per layer (8-bit codes)
    coeff_q: list  # int64 [Din, G+K, Dout] per layer
    coeff_scale: list  # float per layer
    wb: list  # f32 [Din, Dout] per layer

    def lut_dequant(self, i: int) -> np.ndarray:
        full_q = quant.expand_sh_lut(self.specs[i], self.sh_luts[i])
        return quant.dequantize_lut(full_q, self.cfg.n_bits).astype(np.float32)


def quantize_kan(params, ranges, cfg: KanConfig) -> QuantizedKan:
    """ASP-KAN-HAQ post-training quantization of a trained float KAN."""
    specs, sh_luts, cqs, scales, wbs = [], [], [], [], []
    for p, (lo, hi) in zip(params, ranges):
        spec = quant.AspQuantSpec.build(cfg.g, cfg.k, cfg.n_bits, lo, hi)
        specs.append(spec)
        sh_luts.append(quant.quantize_lut(quant.build_sh_lut(spec), cfg.n_bits))
        cq, sc = quant.quantize_coeff(np.asarray(p["coeff"]), bits=8)
        cqs.append(cq)
        scales.append(sc)
        wbs.append(np.asarray(p["wb"], dtype=np.float32))
    return QuantizedKan(cfg, specs, sh_luts, cqs, scales, wbs)


def quantized_forward(qk: QuantizedKan, x):
    """Inference graph lowered to HLO: fused Pallas layers + requantization."""
    h = x
    for i, spec in enumerate(qk.specs):
        xq = quant.quantize(spec, h)
        lut = jnp.asarray(qk.lut_dequant(i))
        coeff = jnp.asarray(qk.coeff_q[i], jnp.float32) * qk.coeff_scale[i]
        h = kan_spline.kan_layer(xq, lut, coeff, jnp.asarray(qk.wb[i]), spec)
    return h


# ---------------------------------------------------------------------------
# Traditional MLP baseline (Fig 13): 17 x 420 x 420 x 14 = 190,274 params,
# matching the paper's 190,214 +-0.03%.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    dims: tuple

    @property
    def num_params(self) -> int:
        return sum((a + 1) * b for a, b in zip(self.dims[:-1], self.dims[1:]))


def init_mlp(cfg: MlpConfig, key) -> list:
    params = []
    for din, dout in zip(cfg.dims[:-1], cfg.dims[1:]):
        key, k1 = jax.random.split(key)
        params.append(
            {
                "w": jax.random.normal(k1, (din, dout), jnp.float32)
                * jnp.sqrt(2.0 / din),
                "b": jnp.zeros((dout,), jnp.float32),
            }
        )
    return params


def mlp_forward(params, x):
    h = x
    for i, p in enumerate(params):
        h = h @ p["w"] + p["b"]
        if i + 1 < len(params):
            h = jnp.maximum(h, 0.0)
    return h
