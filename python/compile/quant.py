"""ASP-KAN-HAQ: Alignment-Symmetry and PowerGap KAN hardware-aware quantization.

Implements the two phases of Section 3.1 of the paper:

* Phase 1 (Alignment-Symmetry): the quantization grid is constrained to an
  integer multiple of the knot grid, ``G * L <= 2**n`` (eq. 4). With zero
  offset between the two grids, every B_i(x) sees the *same* set of quantized
  abscissae inside its support, so one LUT can be shared by all G+K basis
  functions. Uniform B-splines are symmetric, which halves the shared LUT:
  the Sharable-Hemi LUT (SH-LUT).

* Phase 2 (PowerGap): restrict ``L = 2**LD`` (eq. 5/6) so that the global
  interval index and the local offset become bit-field extractions::

      j = x_q >> LD        # which knot interval -> which B(X) are active
      l = x_q &  (2**LD-1) # position inside the interval -> SH-LUT row

  which is what lets the paper replace one n-bit decoder with an
  (n-D)-bit + D-bit pair and collapse the TG-MUX tree.

The same math is implemented in ``rust/src/quant`` (the authoritative
hardware-path implementation); this module is the training/export side and
the oracle the Pallas kernel is tested against.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from compile.kernels import ref


def solve_ld(g: int, n: int) -> int:
    """Largest LD with ``G * 2**LD <= 2**n`` (eq. 6). Requires g <= 2**n."""
    if g < 1:
        raise ValueError(f"grid size must be >= 1, got {g}")
    if g > 2**n:
        raise ValueError(f"G={g} does not fit in {n}-bit input precision")
    ld = int(math.floor(math.log2((2**n) / g)))
    # guard against float edge cases: enforce the inequality exactly
    while g * 2 ** (ld + 1) <= 2**n:
        ld += 1
    while g * 2**ld > 2**n:
        ld -= 1
    return ld


@dataclasses.dataclass(frozen=True)
class AspQuantSpec:
    """Quantization geometry for one KAN layer input under ASP-KAN-HAQ."""

    g: int  # knot grid size (number of intervals)
    k: int  # B-spline degree
    n_bits: int  # input precision
    ld: int  # PowerGap exponent, L = 2**ld
    lo: float  # float value mapped to code 0
    hi: float  # float value mapped to code R (one past the last code)

    @property
    def levels_per_interval(self) -> int:
        return 1 << self.ld

    @property
    def range(self) -> int:
        """Number of input codes R = G * 2**LD (codes are 0..R-1)."""
        return self.g * (1 << self.ld)

    @property
    def step(self) -> float:
        """Quantization step delta = (hi - lo) / R."""
        return (self.hi - self.lo) / self.range

    @property
    def knot_spacing(self) -> float:
        return (self.hi - self.lo) / self.g

    @property
    def num_basis(self) -> int:
        return self.g + self.k

    @classmethod
    def build(cls, g: int, k: int, n_bits: int, lo: float, hi: float) -> "AspQuantSpec":
        if not hi > lo:
            raise ValueError(f"empty input range [{lo}, {hi}]")
        return cls(g=g, k=k, n_bits=n_bits, ld=solve_ld(g, n_bits), lo=lo, hi=hi)


def quantize(spec: AspQuantSpec, x):
    """Float -> input code in [0, R-1] (round-to-nearest, saturating)."""
    q = jnp.round((jnp.asarray(x) - spec.lo) / spec.step)
    return jnp.clip(q, 0, spec.range - 1).astype(jnp.int32)


def dequantize(spec: AspQuantSpec, xq):
    """Input code -> float on the aligned grid (code k maps to lo + k*step)."""
    return spec.lo + xq.astype(jnp.float32) * spec.step


def grid_coord(spec: AspQuantSpec, xq):
    """Code -> grid coordinate z in [0, G): exact because of alignment."""
    return xq.astype(jnp.float32) / float(spec.levels_per_interval)


def build_lut(spec: AspQuantSpec) -> np.ndarray:
    """Full shared LUT, shape [2**LD, K+1].

    Row ``l`` holds the K+1 *active* basis values for any code with local
    offset ``l``: for a code in interval ``j``, the active bases are
    ``B_{j+t}, t = 0..K`` and ``B_{j+t}(x) = C_K(K - t + l / 2**LD)``.

    Because of Alignment-Symmetry this one table serves every interval of
    every B(X) -- the whole point of phase 1.
    """
    lvl = spec.levels_per_interval
    u = np.arange(lvl, dtype=np.float32) / lvl  # local fraction
    t = np.arange(spec.k + 1, dtype=np.float32)
    s = spec.k - t[None, :] + u[:, None]  # [lvl, K+1]
    return np.asarray(ref.cardinal_bspline(jnp.asarray(s), spec.k), dtype=np.float32)


def build_sh_lut(spec: AspQuantSpec) -> np.ndarray:
    """Sharable-Hemi LUT: only rows 0..2**(LD-1), shape [2**(LD-1)+1, K+1].

    The symmetry C_K(s) = C_K(K+1-s) gives
    ``LUT[l, t] = LUT[(2**LD - l) % 2**LD, K-1-t]`` so the upper half of the
    full LUT mirrors the lower half -- the paper's 50% LUT size reduction.
    """
    full = build_lut(spec)
    half = spec.levels_per_interval // 2
    return full[: half + 1].copy()


def expand_sh_lut(spec: AspQuantSpec, sh: np.ndarray) -> np.ndarray:
    """Reconstruct the full LUT from an SH-LUT (what the MUX network does)."""
    lvl = spec.levels_per_interval
    full = np.zeros((lvl, spec.k + 1), dtype=sh.dtype)
    half = lvl // 2
    full[: half + 1] = sh
    for l in range(half + 1, lvl):
        full[l] = sh[lvl - l][::-1]
    # row 0 of the mirror pairs with itself reversed; consistency is a test
    return full


def quantize_lut(lut: np.ndarray, bits: int = 8) -> np.ndarray:
    """LUT entries to unsigned fixed point (B values are in [0, 1])."""
    scale = (1 << bits) - 1
    return np.clip(np.round(lut * scale), 0, scale).astype(np.int64)


def dequantize_lut(lut_q: np.ndarray, bits: int = 8) -> np.ndarray:
    return lut_q.astype(np.float32) / float((1 << bits) - 1)


def decompose(spec: AspQuantSpec, xq):
    """PowerGap bit-field split: code -> (global interval j, local offset l)."""
    xq = jnp.asarray(xq)
    j = jnp.right_shift(xq, spec.ld)
    l = jnp.bitwise_and(xq, spec.levels_per_interval - 1)
    return j, l


def quantize_coeff(c: np.ndarray, bits: int = 8):
    """Symmetric per-tensor int quantization of the spline coefficients ci'.

    Returns (int array in [-(2^{b-1}-1), 2^{b-1}-1], scale). ci' is what gets
    programmed into the RRAM cells; 8-bit per the paper.
    """
    qmax = (1 << (bits - 1)) - 1
    amax = float(np.max(np.abs(c))) if c.size else 0.0
    scale = amax / qmax if amax > 0 else 1.0
    cq = np.clip(np.round(c / scale), -qmax, qmax).astype(np.int64)
    return cq, scale


# ---------------------------------------------------------------------------
# Conventional-quantization baseline (PACT-style), for the Fig 10 comparison.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PactQuantSpec:
    """PACT-style conventional quantization: clipping range [0, alpha] split
    into 2**n uniform steps with *no* relationship to the knot grid.

    The quantization step is generally incommensurate with the knot spacing,
    so the quantized abscissae fall at *different* offsets inside different
    knot intervals -> every B_i(x) needs its own LUT (the paper's Fig 2/3
    problem). We model that faithfully: per-basis LUTs over each basis'
    support.
    """

    g: int
    k: int
    n_bits: int
    lo: float
    alpha: float  # PACT clipping parameter (hi)

    @property
    def range(self) -> int:
        return 1 << self.n_bits

    @property
    def step(self) -> float:
        return (self.alpha - self.lo) / self.range

    def quantize(self, x):
        q = jnp.round((jnp.asarray(x) - self.lo) / self.step)
        return jnp.clip(q, 0, self.range - 1).astype(jnp.int32)

    def per_basis_lut_entries(self) -> int:
        """Quantized points inside one basis' support: (K+1)/G of the range."""
        return int(math.ceil((self.k + 1) * self.range / self.g))

    def build_per_basis_luts(self) -> np.ndarray:
        """LUT for each basis i: B_i at every code in its support.

        Shape [G+K, ceil((K+1) * 2**n / G)]. Misalignment means these tables
        genuinely differ between bases (asserted in tests), which is why the
        conventional design cannot share them.
        """
        entries = self.per_basis_lut_entries()
        h = (self.alpha - self.lo) / self.g
        out = np.zeros((self.g + self.k, entries), dtype=np.float32)
        codes = np.arange(self.range, dtype=np.float32)
        x = self.lo + codes * self.step
        z = (x - self.lo) / h  # grid coordinate of every code
        basis = np.asarray(ref.basis_functions(jnp.asarray(z), self.g, self.k))
        for i in range(self.g + self.k):
            # support of basis i in grid coords is [i-k, i+1]
            zlo, zhi = i - self.k, i + 1
            mask = (z >= zlo) & (z < zhi)
            vals = basis[mask, i]
            out[i, : min(entries, vals.size)] = vals[:entries]
        return out
