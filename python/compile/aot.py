"""AOT build path: train the paper's models, quantize with ASP-KAN-HAQ,
export HLO text + quantized weights + dataset into ``artifacts/``.

Run once by ``make artifacts``::

    python python/compile/aot.py --out artifacts

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the rust side's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs:
  manifest.json               index of everything below + accuracies
  dataset.json                test split + calibration sample
  <model>.weights.json        quantized weights for the rust ACIM simulator
  <model>.b{1,32}.hlo.txt     AOT-lowered inference graphs (PJRT backend)
  sweep/kan_g{7,15,30,60}.weights.json   Fig 12 models
  sweep/sweep.json            G-sweep manifest for KAN-NeuroSim (Fig 9/13)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import datasets
from compile import model as M
from compile import train as T

BATCH_SIZES = (1, 32)
SWEEP_GS = (7, 15, 30, 60)  # Fig 12 pairing with array sizes 128..1024
KAN1 = M.KanConfig(dims=(17, 1, 14), g=5)  # 279 params, paper's KAN1
KAN2 = M.KanConfig(dims=(17, 2, 14), g=32)  # 2232 params, paper's KAN2
MLP = M.MlpConfig(dims=(17, 420, 420, 14))  # 190,274 params (paper: 190,214)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for the loader).

    ``print_large_constants=True`` is load-bearing: the default HLO printer
    elides big literals as ``constant({...})``, which the rust-side text
    parser silently turns into zero tensors -- the whole model evaluates to
    zeros (EXPERIMENTS.md lessons-learned).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_hlo(fn, batch: int, din: int, path: str) -> None:
    spec = jax.ShapeDtypeStruct((batch, din), jnp.float32)
    lowered = jax.jit(lambda x: (fn(x),)).lower(spec)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))


def kan_weights_payload(name: str, cfg: M.KanConfig, qk: M.QuantizedKan, extra: dict):
    layers = []
    for i, spec in enumerate(qk.specs):
        layers.append(
            {
                "din": int(cfg.dims[i]),
                "dout": int(cfg.dims[i + 1]),
                "lo": spec.lo,
                "hi": spec.hi,
                "ld": spec.ld,
                "sh_lut": qk.sh_luts[i].tolist(),
                "coeff_q": np.asarray(qk.coeff_q[i]).astype(int).ravel().tolist(),
                "coeff_scale": float(qk.coeff_scale[i]),
                "wb": np.asarray(qk.wb[i]).astype(float).ravel().tolist(),
            }
        )
    return {
        "name": name,
        "kind": "kan",
        "dims": list(cfg.dims),
        "g": cfg.g,
        "k": cfg.k,
        "n_bits": cfg.n_bits,
        "num_params": cfg.num_params,
        "layers": layers,
        **extra,
    }


def mlp_weights_payload(name: str, cfg: M.MlpConfig, params, extra: dict):
    layers = []
    for i, p in enumerate(params):
        layers.append(
            {
                "din": int(cfg.dims[i]),
                "dout": int(cfg.dims[i + 1]),
                "w": np.asarray(p["w"]).astype(float).ravel().tolist(),
                "b": np.asarray(p["b"]).astype(float).ravel().tolist(),
            }
        )
    return {
        "name": name,
        "kind": "mlp",
        "dims": list(cfg.dims),
        "num_params": cfg.num_params,
        "layers": layers,
        **extra,
    }


def eval_quantized(qk: M.QuantizedKan, x: np.ndarray, y: np.ndarray) -> float:
    logits = M.quantized_forward(qk, jnp.asarray(x))
    return float(jnp.mean(jnp.argmax(logits, axis=1) == jnp.asarray(y)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--fast", action="store_true", help="cut epochs (CI smoke)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    os.makedirs(os.path.join(args.out, "sweep"), exist_ok=True)
    t0 = time.time()

    ep = 0.25 if args.fast else 1.0
    data = datasets.generate(seed=args.seed)
    manifest = {
        "format": 1,
        "seed": args.seed,
        "dataset": {
            "num_features": datasets.NUM_FEATURES,
            "num_classes": datasets.NUM_CLASSES,
            "train": int(data.train_x.shape[0]),
            "val": int(data.val_x.shape[0]),
            "test": int(data.test_x.shape[0]),
        },
        "models": {},
        "sweep": [],
        "batch_sizes": list(BATCH_SIZES),
    }

    with open(os.path.join(args.out, "dataset.json"), "w") as f:
        json.dump(
            {
                "test_x": data.test_x.ravel().tolist(),
                "test_y": data.test_y.tolist(),
                "calib_x": data.train_x[:1000].ravel().tolist(),
                "calib_y": data.train_y[:1000].tolist(),
                "num_features": datasets.NUM_FEATURES,
                "num_classes": datasets.NUM_CLASSES,
            },
            f,
        )

    test_x, test_y = data.test_x, data.test_y

    # ---- KAN models (train float -> ASP-KAN-HAQ PTQ -> export) ----------
    for name, cfg, epochs in (
        ("kan1", KAN1, int(400 * ep)),
        ("kan2", KAN2, int(300 * ep)),
    ):
        print(f"[aot] training {name} dims={cfg.dims} G={cfg.g} ...", flush=True)
        res = T.train_kan(cfg, data, epochs=epochs, seed=args.seed)
        qk = M.quantize_kan(res.params, res.ranges, cfg)
        float_logits = M.kan_forward(
            res.params, jnp.asarray(test_x), res.ranges, cfg
        )
        float_acc = T.accuracy(float_logits, jnp.asarray(test_y))
        quant_acc = eval_quantized(qk, test_x, test_y)
        print(
            f"[aot] {name}: val={res.val_acc:.4f} test(float)={float_acc:.4f} "
            f"test(quant)={quant_acc:.4f}",
            flush=True,
        )
        payload = kan_weights_payload(
            name, cfg, qk, {"float_test_acc": float_acc, "quant_test_acc": quant_acc}
        )
        with open(os.path.join(args.out, f"{name}.weights.json"), "w") as f:
            json.dump(payload, f)
        hlo_files = {}
        for b in BATCH_SIZES:
            path = os.path.join(args.out, f"{name}.b{b}.hlo.txt")
            export_hlo(lambda x: M.quantized_forward(qk, x), b, cfg.dims[0], path)
            hlo_files[str(b)] = os.path.basename(path)
        manifest["models"][name] = {
            "kind": "kan",
            "dims": list(cfg.dims),
            "g": cfg.g,
            "k": cfg.k,
            "num_params": cfg.num_params,
            "val_acc": res.val_acc,
            "float_test_acc": float_acc,
            "quant_test_acc": quant_acc,
            "weights": f"{name}.weights.json",
            "hlo": hlo_files,
        }

    # ---- MLP baseline ----------------------------------------------------
    print(f"[aot] training mlp dims={MLP.dims} ...", flush=True)
    mres = T.train_mlp(MLP, data, epochs=int(250 * ep), seed=args.seed)
    mlp_test_acc = T.accuracy(
        M.mlp_forward(mres.params, jnp.asarray(test_x)), jnp.asarray(test_y)
    )
    print(f"[aot] mlp: val={mres.val_acc:.4f} test={mlp_test_acc:.4f}", flush=True)
    with open(os.path.join(args.out, "mlp.weights.json"), "w") as f:
        json.dump(
            mlp_weights_payload("mlp", MLP, mres.params, {"test_acc": mlp_test_acc}), f
        )
    hlo_files = {}
    for b in BATCH_SIZES:
        path = os.path.join(args.out, f"mlp.b{b}.hlo.txt")
        export_hlo(lambda x: M.mlp_forward(mres.params, x), b, MLP.dims[0], path)
        hlo_files[str(b)] = os.path.basename(path)
    manifest["models"]["mlp"] = {
        "kind": "mlp",
        "dims": list(MLP.dims),
        "num_params": MLP.num_params,
        "val_acc": mres.val_acc,
        "test_acc": mlp_test_acc,
        "weights": "mlp.weights.json",
        "hlo": hlo_files,
    }

    # ---- Fig 12 G-sweep (17x1x14, G = 7/15/30/60 <-> arrays 128..1024) ---
    for g in SWEEP_GS:
        cfg = M.KanConfig(dims=(17, 1, 14), g=g)
        print(f"[aot] sweep: training G={g} ...", flush=True)
        res = T.train_kan(cfg, data, epochs=int(250 * ep), seed=args.seed)
        qk = M.quantize_kan(res.params, res.ranges, cfg)
        quant_acc = eval_quantized(qk, test_x, test_y)
        payload = kan_weights_payload(
            f"kan_g{g}", cfg, qk, {"quant_test_acc": quant_acc}
        )
        fname = f"sweep/kan_g{g}.weights.json"
        with open(os.path.join(args.out, fname), "w") as f:
            json.dump(payload, f)
        manifest["sweep"].append(
            {
                "g": g,
                "num_params": cfg.num_params,
                "val_acc": res.val_acc,
                "quant_test_acc": quant_acc,
                "weights": fname,
            }
        )
        print(f"[aot] sweep G={g}: val={res.val_acc:.4f} quant={quant_acc:.4f}")

    with open(os.path.join(args.out, "sweep", "sweep.json"), "w") as f:
        json.dump(manifest["sweep"], f, indent=2)

    manifest["build_seconds"] = round(time.time() - t0, 1)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] done in {manifest['build_seconds']}s -> {args.out}/", flush=True)


if __name__ == "__main__":
    main()
