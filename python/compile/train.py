"""Build-time training: hand-rolled Adam (no optax in the image), the KAN /
MLP training loops, and the grid-extension procedure of Fig 9 (KAN-NeuroSim
step 2).

All of this runs exactly once, inside ``make artifacts``; nothing here is on
the request path.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.kernels import ref


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=1e-2, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads
    )
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return params, {"m": m, "v": v, "t": t}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits, labels) -> float:
    return float(jnp.mean(jnp.argmax(logits, axis=1) == labels))


# ---------------------------------------------------------------------------
# KAN training
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainResult:
    params: list
    ranges: list
    val_acc: float
    val_loss: float
    epochs_run: int


def _make_kan_step(cfg: M.KanConfig, ranges, lr):
    ranges = tuple((float(a), float(b)) for a, b in ranges)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            return cross_entropy(M.kan_forward(p, x, ranges, cfg), y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    return step


def train_kan(
    cfg: M.KanConfig,
    data,
    *,
    epochs: int = 200,
    batch: int = 512,
    lr: float = 2e-2,
    seed: int = 0,
    params=None,
    ranges=None,
) -> TrainResult:
    """Train a KAN with fixed grid ranges (recalibrated once mid-training)."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = M.init_kan(cfg, key)
    x_all = jnp.asarray(data.train_x)
    y_all = jnp.asarray(data.train_y)
    if ranges is None:
        # input features live in [-1, 1]; hidden ranges start wide and get
        # recalibrated after a warmup third of the run
        ranges = M.calibrate_ranges(params, x_all, cfg)
    step = _make_kan_step(cfg, ranges, lr)
    opt = adam_init(params)

    n = x_all.shape[0]
    nb = max(1, n // batch)
    rng = np.random.default_rng(seed)
    recal_at = max(1, epochs // 3)
    for epoch in range(epochs):
        perm = rng.permutation(n)
        for i in range(nb):
            idx = perm[i * batch : (i + 1) * batch]
            params, opt, _ = step(params, opt, x_all[idx], y_all[idx])
        if epoch + 1 == recal_at:
            ranges = M.calibrate_ranges(params, x_all, cfg)
            step = _make_kan_step(cfg, ranges, lr * 0.5)

    val_logits = M.kan_forward(params, jnp.asarray(data.val_x), ranges, cfg)
    return TrainResult(
        params=params,
        ranges=ranges,
        val_acc=accuracy(val_logits, jnp.asarray(data.val_y)),
        val_loss=float(cross_entropy(val_logits, jnp.asarray(data.val_y))),
        epochs_run=epochs,
    )


# ---------------------------------------------------------------------------
# Grid extension (original-KAN technique; KAN-NeuroSim step 2, Fig 9)
# ---------------------------------------------------------------------------


def extend_grid(params, ranges, cfg_old: M.KanConfig, g_new: int):
    """Refit spline coefficients on a finer grid by least squares.

    Evaluates each layer's learned spline on a dense sample of its range and
    solves for coefficients of the G_new-grid basis that reproduce it -- the
    grid-extension method of the original KAN paper.
    """
    cfg_new = M.KanConfig(cfg_old.dims, g_new, cfg_old.k, cfg_old.n_bits)
    out = []
    for p, (lo, hi) in zip(params, ranges):
        din, _, dout = p["coeff"].shape
        zs_new = jnp.linspace(0.0, float(g_new), 4 * (g_new + cfg_old.k))
        xs = lo + zs_new / g_new * (hi - lo)
        z_old = (xs - lo) / ((hi - lo) / cfg_old.g)
        basis_old = ref.basis_functions(z_old, cfg_old.g, cfg_old.k)  # [S, G+K]
        basis_new = ref.basis_functions(zs_new, g_new, cfg_old.k)  # [S, Gn+K]
        # target spline values per (i, o): [S, Din*Dout]
        target = jnp.einsum("sg,igo->sio", basis_old, p["coeff"]).reshape(
            basis_old.shape[0], -1
        )
        sol = jnp.linalg.lstsq(basis_new, target)[0]  # [Gn+K, Din*Dout]
        coeff_new = sol.reshape(g_new + cfg_old.k, din, dout).transpose(1, 0, 2)
        out.append({"coeff": coeff_new, "wb": p["wb"]})
    return out, cfg_new


@dataclasses.dataclass
class GridExtensionLog:
    gs: list
    val_losses: list
    val_accs: list
    hw_ok: list
    final_g: int


def train_with_grid_extension(
    dims,
    data,
    *,
    g_init: int = 3,
    extend_factor: int = 2,
    max_g: int = 64,
    epochs_per_stage: int = 80,
    hw_ok=lambda g: True,
    seed: int = 0,
    k: int = 3,
) -> tuple:
    """Fig 9 loop: train N epochs, extend G while validation loss improves
    *and* the hardware constraint check (NeuroSim role) passes; otherwise
    revert to G_pre and stop.
    """
    cfg = M.KanConfig(tuple(dims), g_init, k)
    res = train_kan(cfg, data, epochs=epochs_per_stage, seed=seed)
    log = GridExtensionLog(
        gs=[g_init],
        val_losses=[res.val_loss],
        val_accs=[res.val_acc],
        hw_ok=[bool(hw_ok(g_init))],
        final_g=g_init,
    )
    best = (cfg, res)
    g = g_init
    while g * extend_factor <= max_g:
        g_next = g * extend_factor
        if not hw_ok(g_next):
            log.gs.append(g_next)
            log.val_losses.append(float("nan"))
            log.val_accs.append(float("nan"))
            log.hw_ok.append(False)
            break
        params_new, cfg_new = extend_grid(best[1].params, best[1].ranges, best[0], g_next)
        res_new = train_kan(
            cfg_new,
            data,
            epochs=epochs_per_stage,
            seed=seed,
            params=params_new,
            ranges=best[1].ranges,
        )
        log.gs.append(g_next)
        log.val_losses.append(res_new.val_loss)
        log.val_accs.append(res_new.val_acc)
        log.hw_ok.append(True)
        if res_new.val_loss >= best[1].val_loss:
            break  # validation loss no longer decreasing -> revert to G_pre
        best = (cfg_new, res_new)
        g = g_next
    log.final_g = best[0].g
    return best[0], best[1], log


# ---------------------------------------------------------------------------
# MLP baseline training
# ---------------------------------------------------------------------------


def train_mlp(
    cfg: M.MlpConfig,
    data,
    *,
    epochs: int = 250,
    batch: int = 256,
    lr: float = 1e-3,
    weight_decay: float = 3e-3,
    seed: int = 0,
) -> TrainResult:
    """Train the MLP baseline.

    The 190k-parameter MLP overfits the 4k-sample training set badly without
    regularization (train 100% / val <50%); L2 weight decay of 3e-3 is the
    best setting found in a sweep (see EXPERIMENTS.md) and is what a
    practitioner would deploy -- the baseline is tuned in good faith, not
    sandbagged.
    """
    key = jax.random.PRNGKey(seed)
    params = M.init_mlp(cfg, key)

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            ce = cross_entropy(M.mlp_forward(p, x), y)
            l2 = sum(jnp.sum(q["w"] ** 2) for q in p)
            return ce + weight_decay * l2

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    opt = adam_init(params)
    x_all = jnp.asarray(data.train_x)
    y_all = jnp.asarray(data.train_y)
    n = x_all.shape[0]
    nb = max(1, n // batch)
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(nb):
            idx = perm[i * batch : (i + 1) * batch]
            params, opt, _ = step(params, opt, x_all[idx], y_all[idx])

    val_logits = M.mlp_forward(params, jnp.asarray(data.val_x))
    return TrainResult(
        params=params,
        ranges=[],
        val_acc=accuracy(val_logits, jnp.asarray(data.val_y)),
        val_loss=float(cross_entropy(val_logits, jnp.asarray(data.val_y))),
        epochs_run=epochs,
    )
