"""L2 model tests: shapes, quantized forward fidelity, calibration."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import datasets, model as M, quant


def small_kan():
    cfg = M.KanConfig(dims=(6, 3, 4), g=5)
    params = M.init_kan(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_param_counts_match_paper():
    assert M.KanConfig(dims=(17, 1, 14), g=5).num_params == 279
    assert M.KanConfig(dims=(17, 2, 14), g=32).num_params == 2232
    assert M.MlpConfig(dims=(17, 420, 420, 14)).num_params == 190_274


def test_forward_shapes():
    cfg, params = small_kan()
    x = jnp.zeros((9, 6))
    ranges = [(-1.0, 1.0)] * cfg.num_layers
    y = M.kan_forward(params, x, ranges, cfg)
    assert y.shape == (9, 4)


def test_calibrate_ranges_covers_activations():
    cfg, params = small_kan()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(-1, 1, (50, 6)).astype(np.float32))
    ranges = M.calibrate_ranges(params, x, cfg)
    assert len(ranges) == cfg.num_layers
    for lo, hi in ranges:
        assert hi > lo
    # layer-0 range covers the input span
    assert ranges[0][0] <= float(x.min()) and ranges[0][1] >= float(x.max())


def test_quantized_forward_close_to_float():
    cfg, params = small_kan()
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(-1, 1, (40, 6)).astype(np.float32))
    ranges = M.calibrate_ranges(params, x, cfg)
    qk = M.quantize_kan(params, ranges, cfg)
    y_float = np.asarray(M.kan_forward(params, x, ranges, cfg))
    y_quant = np.asarray(M.quantized_forward(qk, x))
    # 8-bit weights/LUT/activations: expect small relative error
    scale = np.abs(y_float).max() + 1e-6
    assert np.abs(y_quant - y_float).max() / scale < 0.15


def test_quantized_predictions_mostly_match_float():
    cfg, params = small_kan()
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(-1, 1, (200, 6)).astype(np.float32))
    ranges = M.calibrate_ranges(params, x, cfg)
    qk = M.quantize_kan(params, ranges, cfg)
    pf = np.argmax(np.asarray(M.kan_forward(params, x, ranges, cfg)), axis=1)
    pq = np.argmax(np.asarray(M.quantized_forward(qk, x)), axis=1)
    assert (pf == pq).mean() > 0.9


def test_mlp_forward():
    cfg = M.MlpConfig(dims=(4, 8, 3))
    params = M.init_mlp(cfg, jax.random.PRNGKey(0))
    y = M.mlp_forward(params, jnp.zeros((5, 4)))
    assert y.shape == (5, 3)
    # zero input -> logits equal the output bias (zeros at init)
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)


def test_dataset_shapes_and_determinism():
    a = datasets.generate(n=600, seed=11)
    b = datasets.generate(n=600, seed=11)
    np.testing.assert_array_equal(a.train_x, b.train_x)
    np.testing.assert_array_equal(a.test_y, b.test_y)
    assert a.train_x.shape[1] == datasets.NUM_FEATURES
    assert set(np.unique(a.train_y)).issubset(set(range(datasets.NUM_CLASSES)))
    c = datasets.generate(n=600, seed=12)
    assert not np.array_equal(a.train_y, c.train_y)


def test_dataset_class_distribution_is_peaked():
    d = datasets.generate(n=6000, seed=7)
    hist = np.bincount(d.train_y, minlength=14) / len(d.train_y)
    # central classes dominate the extremes (signature-like distribution)
    assert hist[6] + hist[7] > 5 * (hist[0] + hist[13])
