"""Training-loop tests: learning happens, grid extension refits correctly."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import datasets, model as M, train as T
from compile.kernels import ref


def tiny_data(n=400, seed=5):
    return datasets.generate(n=n, seed=seed)


def test_kan_training_reduces_loss():
    data = tiny_data()
    cfg = M.KanConfig(dims=(17, 1, 14), g=5)
    r_short = T.train_kan(cfg, data, epochs=2, seed=1)
    r_long = T.train_kan(cfg, data, epochs=40, seed=1)
    assert r_long.val_loss < r_short.val_loss
    assert r_long.val_acc > 2.0 / 14.0  # far better than chance


def test_mlp_training_learns():
    data = tiny_data()
    cfg = M.MlpConfig(dims=(17, 32, 14))
    # light decay: the default 3e-3 is tuned for the 190k-param baseline on
    # 4k samples, far too strong for this 1k-param model on 400 samples
    r = T.train_mlp(cfg, data, epochs=120, weight_decay=1e-4, seed=1)
    assert r.val_acc > 0.2


def test_adam_moves_toward_minimum():
    # minimize (p - 3)^2 from 0
    params = {"p": jnp.zeros(())}
    opt = T.adam_init(params)
    for _ in range(300):
        grads = {"p": 2.0 * (params["p"] - 3.0)}
        params, opt = T.adam_update(params, grads, opt, lr=0.05)
    assert abs(float(params["p"]) - 3.0) < 0.05


def test_cross_entropy_sanity():
    logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]])
    labels = jnp.asarray([0, 1])
    assert float(T.cross_entropy(logits, labels)) < 1e-3
    assert float(T.cross_entropy(logits, 1 - labels)) > 5.0


def test_grid_extension_preserves_function():
    """Refitting on a finer grid must (nearly) reproduce the coarse spline."""
    cfg = M.KanConfig(dims=(3, 2), g=4)
    params = M.init_kan(cfg, jax.random.PRNGKey(2))
    ranges = [(-1.0, 1.0)]
    params_new, cfg_new = T.extend_grid(params, ranges, cfg, g_new=8)
    assert cfg_new.g == 8
    x = jnp.linspace(-0.99, 0.99, 64).reshape(-1, 1).repeat(3, axis=1)
    y_old = M.kan_forward(params, x, ranges, cfg)
    y_new = M.kan_forward(params_new, x, ranges, cfg_new)
    err = float(jnp.max(jnp.abs(y_old - y_new)))
    scale = float(jnp.max(jnp.abs(y_old))) + 1e-6
    assert err / scale < 0.05, f"grid extension changed the function: {err / scale}"


def test_grid_extension_loop_respects_hw_constraint():
    data = tiny_data(n=300)
    # hardware gate rejects anything above G=6 -> loop must stop at 6
    cfg, res, log = T.train_with_grid_extension(
        [17, 1, 14],
        data,
        g_init=3,
        extend_factor=2,
        max_g=24,
        epochs_per_stage=3,
        hw_ok=lambda g: g <= 6,
        seed=0,
    )
    assert cfg.g <= 6
    assert log.hw_ok[-1] is False or max(log.gs) <= 6
