"""ASP-KAN-HAQ property tests (python side; the rust side mirrors these)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import quant


@hypothesis.given(
    g=st.integers(min_value=1, max_value=256),
    n=st.sampled_from([6, 8, 10]),
)
def test_solve_ld_is_maximal(g, n):
    hypothesis.assume(g <= 2**n)
    ld = quant.solve_ld(g, n)
    assert g * 2**ld <= 2**n
    assert g * 2 ** (ld + 1) > 2**n


def test_solve_ld_rejects_invalid():
    with pytest.raises(ValueError):
        quant.solve_ld(0, 8)
    with pytest.raises(ValueError):
        quant.solve_ld(257, 8)


@hypothesis.given(
    g=st.sampled_from([2, 5, 8, 13, 32, 64]),
    k=st.integers(min_value=1, max_value=4),
)
def test_knots_align_with_codes(g, k):
    """Every knot boundary lands exactly on a code multiple of 2^LD."""
    spec = quant.AspQuantSpec.build(g, k, 8, -2.0, 3.0)
    for j in range(g):
        knot = spec.lo + j * spec.knot_spacing
        q = int(quant.quantize(spec, knot))
        assert q % spec.levels_per_interval == 0
        assert q >> spec.ld == j


@hypothesis.given(
    g=st.sampled_from([3, 5, 8, 16, 60]),
    k=st.integers(min_value=1, max_value=4),
)
def test_lut_partition_of_unity(g, k):
    spec = quant.AspQuantSpec.build(g, k, 8, 0.0, 1.0)
    lut = quant.build_lut(spec)
    np.testing.assert_allclose(lut.sum(axis=1), 1.0, atol=1e-6)


@hypothesis.given(
    g=st.sampled_from([3, 5, 8, 16, 60]),
    k=st.integers(min_value=1, max_value=4),
)
def test_sh_lut_roundtrip(g, k):
    """Hemi storage + mirror reconstruction == the full table."""
    spec = quant.AspQuantSpec.build(g, k, 8, 0.0, 1.0)
    full = quant.build_lut(spec)
    sh = quant.build_sh_lut(spec)
    assert sh.shape[0] == spec.levels_per_interval // 2 + 1
    rebuilt = quant.expand_sh_lut(spec, sh)
    np.testing.assert_allclose(rebuilt, full, atol=1e-7)


def test_quantize_dequantize_error_bound():
    spec = quant.AspQuantSpec.build(5, 3, 8, -1.0, 1.0)
    x = np.linspace(-1.0, 1.0 - 1e-6, 1000).astype(np.float32)
    xq = quant.quantize(spec, x)
    xd = np.asarray(quant.dequantize(spec, xq))
    # codes top out at R-1 (value hi - step), so inputs near hi carry up to
    # one full step of error; everywhere else it is half a step
    assert np.max(np.abs(xd - x)) <= spec.step + 1e-6
    interior = x < 1.0 - spec.step
    assert np.max(np.abs(xd[interior] - x[interior])) <= spec.step * 0.5 + 1e-6


def test_quantize_coeff_roundtrip():
    rng = np.random.default_rng(0)
    c = rng.normal(0, 0.3, (4, 8, 3))
    cq, scale = quant.quantize_coeff(c, bits=8)
    assert cq.max() <= 127 and cq.min() >= -127
    err = np.abs(cq * scale - c)
    assert err.max() <= scale * 0.5 + 1e-9


def test_quantize_coeff_zero_tensor():
    cq, scale = quant.quantize_coeff(np.zeros((2, 2)), bits=8)
    assert (cq == 0).all()
    assert scale == 1.0


def test_pact_misalignment():
    """Conventional quantization leaves distinct per-basis tables."""
    spec = quant.PactQuantSpec(g=5, k=3, n_bits=8, lo=0.0, alpha=1.0)
    luts = spec.build_per_basis_luts()
    assert luts.shape[0] == 8
    central_diff = np.abs(luts[3] - luts[4]).max()
    assert central_diff > 1e-4, "misaligned grids must differentiate the LUTs"


def test_lut_quantization_8bit():
    spec = quant.AspQuantSpec.build(5, 3, 8, 0.0, 1.0)
    lut_q = quant.quantize_lut(quant.build_lut(spec), bits=8)
    assert lut_q.max() <= 255 and lut_q.min() >= 0
    # quantized rows still sum to ~255 (partition of unity in codes)
    sums = lut_q.sum(axis=1)
    assert (np.abs(sums - 255) <= 2).all()
