"""AOT export tests: HLO text integrity and weight payload schema."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model as M, quant


def test_hlo_text_has_no_elided_constants(tmp_path):
    """The HLO printer must not abbreviate weights as `constant({...})` —
    the rust parser would zero-fill them (the all-zeros-output bug)."""
    w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32))
    lowered = jax.jit(lambda x: (x @ w,)).lower(
        jax.ShapeDtypeStruct((1, 64), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "{...}" not in text
    assert "HloModule" in text


def test_export_hlo_writes_parseable_header(tmp_path):
    path = os.path.join(tmp_path, "m.hlo.txt")
    aot.export_hlo(lambda x: x * 2.0, batch=4, din=3, path=path)
    text = open(path).read()
    assert text.startswith("HloModule")
    assert "f32[4,3]" in text


def test_kan_weights_payload_schema():
    cfg = M.KanConfig(dims=(4, 2), g=5)
    params = M.init_kan(cfg, jax.random.PRNGKey(0))
    qk = M.quantize_kan(params, [(-1.0, 1.0)], cfg)
    payload = aot.kan_weights_payload("t", cfg, qk, {"quant_test_acc": 0.5})
    # must round-trip through json (what the rust loader consumes)
    text = json.dumps(payload)
    back = json.loads(text)
    assert back["dims"] == [4, 2]
    assert back["g"] == 5
    layer = back["layers"][0]
    assert len(layer["coeff_q"]) == 4 * (5 + 3) * 2
    assert len(layer["wb"]) == 8
    assert len(layer["sh_lut"]) == (1 << layer["ld"]) // 2 + 1
    assert all(isinstance(v, int) for v in layer["coeff_q"])


def test_mlp_weights_payload_schema():
    cfg = M.MlpConfig(dims=(3, 4, 2))
    params = M.init_mlp(cfg, jax.random.PRNGKey(1))
    payload = aot.mlp_weights_payload("m", cfg, params, {"test_acc": 0.1})
    back = json.loads(json.dumps(payload))
    assert back["num_params"] == cfg.num_params
    assert len(back["layers"][0]["w"]) == 12
