"""Kernel vs oracle: the core L1 correctness signal.

The Pallas kernels (interpret=True) must match the pure-jnp Cox-de Boor
reference for every (G, K, n_bits, shape) combination; hypothesis sweeps the
space.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import quant
from compile.kernels import kan_spline, ref

hypothesis.settings.register_profile(
    "kan", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kan")


def make_inputs(rng, spec, batch, din, dout):
    x = rng.uniform(spec.lo - 0.3, spec.hi + 0.3, (batch, din)).astype(np.float32)
    xq = np.asarray(quant.quantize(spec, x))
    coeff = rng.normal(0.0, 0.5, (din, spec.num_basis, dout)).astype(np.float32)
    return xq, coeff


def test_spline_mac_matches_ref_basic():
    spec = quant.AspQuantSpec.build(5, 3, 8, -1.0, 1.0)
    rng = np.random.default_rng(0)
    xq, coeff = make_inputs(rng, spec, 64, 17, 14)
    lut = quant.build_lut(spec)
    got = kan_spline.spline_mac(
        jnp.asarray(xq), jnp.asarray(lut), jnp.asarray(coeff), spec
    )
    want = ref.spline_mac_ref(
        quant.grid_coord(spec, jnp.asarray(xq)), jnp.asarray(coeff), spec.g, spec.k
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@hypothesis.given(
    g=st.sampled_from([2, 3, 5, 7, 8, 16, 31, 64]),
    k=st.integers(min_value=1, max_value=4),
    n_bits=st.sampled_from([6, 8]),
    batch=st.sampled_from([1, 3, 32]),
    din=st.integers(min_value=1, max_value=8),
    dout=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_spline_mac_matches_ref_sweep(g, k, n_bits, batch, din, dout, seed):
    hypothesis.assume(g <= 2**n_bits)
    spec = quant.AspQuantSpec.build(g, k, n_bits, -0.7, 1.3)
    rng = np.random.default_rng(seed)
    xq, coeff = make_inputs(rng, spec, batch, din, dout)
    lut = quant.build_lut(spec)
    got = kan_spline.spline_mac(
        jnp.asarray(xq), jnp.asarray(lut), jnp.asarray(coeff), spec
    )
    want = ref.spline_mac_ref(
        quant.grid_coord(spec, jnp.asarray(xq)), jnp.asarray(coeff), spec.g, spec.k
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


@hypothesis.given(
    g=st.sampled_from([4, 5, 12, 32]),
    batch=st.sampled_from([2, 17]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_fused_layer_matches_ref(g, batch, seed):
    k = 3
    spec = quant.AspQuantSpec.build(g, k, 8, -1.0, 1.0)
    rng = np.random.default_rng(seed)
    din, dout = 5, 4
    xq, coeff = make_inputs(rng, spec, batch, din, dout)
    wb = rng.normal(0.0, 1.0, (din, dout)).astype(np.float32)
    lut = quant.build_lut(spec)
    got = kan_spline.kan_layer(
        jnp.asarray(xq), jnp.asarray(lut), jnp.asarray(coeff), jnp.asarray(wb), spec
    )
    x_deq = np.asarray(quant.dequantize(spec, jnp.asarray(xq)))
    want = np.maximum(x_deq, 0.0) @ wb + np.asarray(
        ref.spline_mac_ref(
            quant.grid_coord(spec, jnp.asarray(xq)), jnp.asarray(coeff), g, k
        )
    )
    np.testing.assert_allclose(np.asarray(got), want, atol=5e-5, rtol=1e-5)


def test_block_tiling_invariance():
    """Different batch block sizes must give identical results."""
    spec = quant.AspQuantSpec.build(8, 3, 8, 0.0, 1.0)
    rng = np.random.default_rng(3)
    xq, coeff = make_inputs(rng, spec, 96, 4, 3)
    lut = jnp.asarray(quant.build_lut(spec))
    outs = [
        np.asarray(
            kan_spline.spline_mac(
                jnp.asarray(xq), lut, jnp.asarray(coeff), spec, block=b
            )
        )
        for b in (8, 32, 96)
    ]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_ref_partition_of_unity():
    z = jnp.linspace(0.0, 5.0, 101)[:-1]
    basis = ref.basis_functions(z, 5, 3)
    np.testing.assert_allclose(np.asarray(basis.sum(-1)), 1.0, atol=1e-6)


def test_ref_cardinal_symmetry():
    s = jnp.linspace(0.0, 4.0, 200)
    a = ref.cardinal_bspline(s, 3)
    b = ref.cardinal_bspline(4.0 - s, 3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_out_of_range_inputs_saturate():
    spec = quant.AspQuantSpec.build(5, 3, 8, -1.0, 1.0)
    xq = np.asarray(quant.quantize(spec, np.array([[-99.0, 99.0]])))
    assert xq[0, 0] == 0
    assert xq[0, 1] == spec.range - 1
    # kernel still produces finite values at the saturated codes
    coeff = np.ones((2, spec.num_basis, 1), np.float32)
    lut = quant.build_lut(spec)
    out = kan_spline.spline_mac(
        jnp.asarray(xq), jnp.asarray(lut), jnp.asarray(coeff), spec
    )
    assert np.isfinite(np.asarray(out)).all()
