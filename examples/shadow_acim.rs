//! ACIM shadow-serving walkthrough: serve a KAN on the digital engine
//! with the analog ACIM simulator mirroring half the traffic off the
//! response path, select backends per request over protocol v2, and
//! read the online divergence report — argmax flip rate, logit MAE,
//! per-layer partial-sum error quantiles — from the `metrics` verb.
//! Fully offline (synthetic checkpoint, temp registry).
//!
//! ```sh
//! cargo run --release --example shadow_acim
//! ```

#![allow(clippy::field_reassign_with_default)]

use std::sync::Arc;
use std::time::{Duration, Instant};

use kan_edge::client::{CallOptions, KanClient};
use kan_edge::config::AppConfig;
use kan_edge::coordinator::{BackendKind, Dispatch, TcpServer};
use kan_edge::kan::checkpoint::synthetic_kan_checkpoint;
use kan_edge::registry::{ModelManifest, ModelRegistry};

fn main() -> kan_edge::Result<()> {
    // 1. fresh registry with one dense synthetic KAN, digital primary +
    //    ACIM shadow mirroring 50% of traffic
    let dir = std::env::temp_dir().join("kan_edge_shadow_acim_demo");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    ModelManifest::empty().save(&dir)?;
    let mut cfg = AppConfig::default();
    cfg.artifacts.dir = dir.to_string_lossy().into_owned();
    cfg.artifacts.model = "kan".into();
    cfg.server.backend = BackendKind::Digital;
    cfg.server.shadow.backend = Some(BackendKind::Acim);
    cfg.server.shadow.fraction = 0.5;
    let registry = ModelRegistry::open(&cfg)?;
    let ckpt = synthetic_kan_checkpoint("kan", &[8, 8, 4], 5, 3, 0x5AD);
    let src = dir.join("kan.incoming.json");
    std::fs::write(&src, ckpt.to_value().to_string())?;
    registry.publish_file(&src, None, None)?;

    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target)?;
    println!("serving on {} (digital primary, acim shadow @ 0.5)", server.addr);

    // 2. drive primary traffic; the shadow samples it off-path
    let mut client = KanClient::connect(server.addr)?;
    let mut lg = kan_edge::data::LoadGen::new(0xFEED, 8);
    for _ in 0..100 {
        client.infer(&lg.next_vec())?;
    }
    client.infer_batch(None, lg.batch(100))?;

    // 3. per-request backend selection on the same connection: an
    //    explicitly seeded ACIM request is reproducible bit-for-bit,
    //    and trials > 1 serves an uncertainty estimate
    let row = lg.next_vec();
    let opts = CallOptions {
        backend: Some(BackendKind::Acim),
        seed: Some(42),
        trials: 16,
    };
    let a = client.infer_opts(None, &row, &opts)?;
    let b = client.infer_opts(None, &row, &opts)?;
    assert_eq!(a.logits, b.logits, "fixed (row, seed) must reproduce");
    println!(
        "acim@seed=42, 16 trials: class {} (logit[0] {:.4} ± {:.4})",
        a.class,
        a.logits[0],
        a.std.as_ref().map(|s| s[0]).unwrap_or(0.0)
    );

    // 4. capability descriptor on the control plane
    let info = client.model_info("kan")?;
    if let Some(be) = info.backend {
        println!(
            "served backend: {} (deterministic={}, reference_exact={}), shadow: {:?}",
            be.kind, be.deterministic, be.reference_exact, be.shadow
        );
    }

    // 5. wait for the mirror to drain, then read the divergence report
    let deadline = Instant::now() + Duration::from_secs(20);
    let shadow = loop {
        let body = client.metrics()?;
        let shadow = body
            .field("models")?
            .get("kan@1")
            .and_then(|m| m.get("shadow"))
            .cloned();
        if let Some(s) = &shadow {
            let count = |k: &str| s.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
            if count("mirrored") + count("dropped") + count("errors")
                >= count("sampled")
            {
                break s.clone();
            }
        }
        if Instant::now() > deadline {
            break shadow.unwrap_or(kan_edge::util::json::Value::Null);
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    println!("\nshadow divergence (measured on live traffic):");
    println!("{shadow}");

    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
