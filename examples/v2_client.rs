//! Protocol v2 walkthrough with the typed client: publish two KAN
//! variants into a fresh registry, serve them on one endpoint, then
//! drive it with [`kan_edge::client::KanClient`] — negotiation, control
//! plane, routed inference, whole-batch submit, and pipelined
//! submit/poll with out-of-order completion — while a legacy v1
//! JSON-lines request on the same port still works (auto-detection).
//!
//! ```sh
//! cargo run --release --example v2_client
//! ```

#![allow(clippy::field_reassign_with_default)]

use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

use kan_edge::client::KanClient;
use kan_edge::config::AppConfig;
use kan_edge::coordinator::{BackendKind, Dispatch, TcpServer};
use kan_edge::kan::checkpoint::synthetic_checkpoint_json;
use kan_edge::registry::{ModelManifest, ModelRegistry};
use kan_edge::util::json::Value;

fn main() -> kan_edge::Result<()> {
    // 1. fresh registry with two variants, served on an ephemeral port
    let dir = std::env::temp_dir().join("kan_edge_v2_client_demo");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    ModelManifest::empty().save(&dir)?;
    let mut cfg = AppConfig::default();
    cfg.artifacts.dir = dir.to_string_lossy().into_owned();
    cfg.artifacts.model = "alpha".into();
    cfg.server.backend = BackendKind::Digital;
    let registry = ModelRegistry::open(&cfg)?;
    for (name, favor) in [("alpha", 0), ("beta", 1)] {
        let src = dir.join(format!("{name}.incoming.json"));
        std::fs::write(&src, synthetic_checkpoint_json(name, favor))?;
        registry.publish_file(&src, None, None)?;
    }
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target)?;
    println!("serving on {}", server.addr);

    // 2. connect + negotiate
    let mut client = KanClient::connect(server.addr)?;
    let info = client.server_info();
    println!(
        "negotiated protocol v{} with {} (max_in_flight {})",
        info.protocol, info.server, info.max_in_flight
    );

    // 3. control plane: list, inspect, health
    for m in client.list_models()? {
        println!("  model {}@{} [{}] live={}", m.name, m.version, m.kind, m.live);
    }
    let alpha = client.model_info("alpha")?;
    println!("  alpha digest: {}", alpha.digest.as_deref().unwrap_or("-"));
    let (status, live) = client.health()?;
    println!("  health: {status} ({live} live)");

    // 4. routed inference + whole-batch submit
    let a = client.infer_model(Some("alpha"), &[0.5, 0.5])?;
    let b = client.infer_model(Some("beta"), &[0.5, 0.5])?;
    println!("alpha -> class {} from {}", a.class, a.model);
    println!("beta  -> class {} from {}", b.class, b.model);
    let rows: Vec<Vec<f32>> = (0..32).map(|_| vec![0.5, 0.5]).collect();
    let (model, results) = client.infer_batch(Some("alpha"), rows)?;
    println!("batch of {} rows served by {model}", results.len());

    // 5. pipelined submit/poll: responses come back in completion order
    let mut ids = Vec::new();
    for i in 0..16 {
        ids.push(client.submit(Some("beta"), &[i as f32 * 0.05, 0.1])?);
    }
    let mut completed = 0;
    while completed < ids.len() {
        let (id, outcome) = client.poll()?;
        outcome?;
        completed += 1;
        if completed <= 3 {
            println!("  completion #{completed}: request id {id}");
        }
    }
    println!("pipelined {} requests on one connection", ids.len());

    // 6. the same port still speaks v1 JSON lines (auto-detected)
    let conn = std::net::TcpStream::connect(server.addr)?;
    let mut w = conn.try_clone()?;
    let mut r = BufReader::new(conn);
    w.write_all(b"{\"model\": \"alpha\", \"features\": [0.5, 0.5]}\n")?;
    let mut line = String::new();
    r.read_line(&mut line)?;
    let v = Value::parse(line.trim())?;
    println!(
        "v1 line on the same port -> class {} from {}",
        v.get("class").unwrap().as_i64().unwrap(),
        v.get("model").unwrap().as_str().unwrap()
    );

    // 7. metrics: per-model serving reports + wire counters
    let metrics = client.metrics()?;
    let wire = metrics.field("wire")?;
    println!(
        "wire: v1={} v2={} rows={} in-flight hwm={}",
        wire.get("v1_requests").unwrap(),
        wire.get("v2_requests").unwrap(),
        wire.get("v2_rows").unwrap(),
        wire.get("in_flight_hwm").unwrap()
    );

    server.shutdown();
    Ok(())
}
