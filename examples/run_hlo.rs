//! Debug utility: run an HLO text artifact with a ones input and print output.
use kan_edge::runtime::PjrtEngine;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let (path, b, din, dout) = (
        args[1].clone(),
        args[2].parse::<usize>().unwrap(),
        args[3].parse::<usize>().unwrap(),
        args[4].parse::<usize>().unwrap(),
    );
    let engine = PjrtEngine::cpu().unwrap();
    let exe = engine.load_hlo(&path, b, din, dout).unwrap();
    let x: Vec<f32> = (0..b * din).map(|i| (i % 7) as f32 * 0.1 - 0.2).collect();
    let y = exe.run(&x).unwrap();
    println!("out: {:?}", &y[..y.len().min(20)]);
}
