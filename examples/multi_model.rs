//! Multi-model serving walkthrough: publish two KAN variants into a
//! fresh registry, serve them through one TCP endpoint, route requests
//! per model, then hot-publish a new version and watch traffic switch —
//! all offline (synthetic checkpoints, digital backend).
//!
//! ```sh
//! cargo run --release --example multi_model
//! ```

#![allow(clippy::field_reassign_with_default)]

use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

use kan_edge::config::AppConfig;
use kan_edge::coordinator::{BackendKind, Dispatch, TcpServer};
use kan_edge::kan::checkpoint::synthetic_checkpoint_json as kan_variant_json;
use kan_edge::registry::{ModelManifest, ModelRegistry};
use kan_edge::util::json::Value;

fn ask(addr: std::net::SocketAddr, body: &str) -> Value {
    let conn = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut conn = conn;
    conn.write_all(body.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Value::parse(&line).unwrap()
}

fn main() -> kan_edge::Result<()> {
    let dir = std::env::temp_dir().join("kan_edge_multi_model_demo");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // 1. bootstrap a fresh registry and publish two variants
    ModelManifest::empty().save(&dir)?;
    let mut cfg = AppConfig::default();
    cfg.artifacts.dir = dir.to_string_lossy().into_owned();
    cfg.artifacts.model = "alpha".into();
    cfg.server.backend = BackendKind::Digital;
    let registry = ModelRegistry::open(&cfg)?;

    for (name, favor) in [("alpha", 0), ("beta", 1)] {
        let src = dir.join(format!("{name}.incoming.json"));
        std::fs::write(&src, kan_variant_json(name, favor))?;
        let (published, meta) = registry.publish_file(&src, None, None)?;
        println!(
            "published {published}@{} (digest {})",
            meta.version,
            meta.digest.as_deref().unwrap_or("?")
        );
    }

    // 2. one TCP endpoint serves both; requests pick a variant
    let target: Arc<dyn Dispatch> = registry.clone();
    let server = TcpServer::spawn("127.0.0.1:0", target)?;
    println!("serving on {}", server.addr);
    for body in [
        r#"{"features": [0.5, 0.5]}"#,
        r#"{"model": "alpha", "features": [0.5, 0.5]}"#,
        r#"{"model": "beta",  "features": [0.5, 0.5]}"#,
    ] {
        let v = ask(server.addr, body);
        println!(
            "  {body} -> class {} from {}",
            v.get("class").unwrap().as_i64().unwrap(),
            v.get("model").unwrap().as_str().unwrap()
        );
    }

    // 3. hot-publish alpha v2 with flipped weights: traffic switches,
    //    no restart, no dropped requests
    let src = dir.join("alpha.incoming.json");
    std::fs::write(&src, kan_variant_json("alpha", 1))?;
    let (_, meta) = registry.publish_file(&src, None, None)?;
    println!("hot-published alpha@{}", meta.version);
    let v = ask(server.addr, r#"{"model": "alpha", "features": [0.5, 0.5]}"#);
    println!(
        "  alpha now answers class {} from {}",
        v.get("class").unwrap().as_i64().unwrap(),
        v.get("model").unwrap().as_str().unwrap()
    );

    // 4. per-model metrics with an aggregate rollup
    println!("\nper-model metrics:");
    for (id, r) in registry.metrics() {
        println!("  {id:<10} requests={} p50={}us", r.requests, r.latency_p50_us);
    }
    let agg = registry.aggregate_metrics();
    println!("  {:<10} requests={}", "TOTAL", agg.requests);

    server.shutdown();
    Ok(())
}
