//! KAN-NeuroSim co-search demo (paper §3.4, Fig 9).
//!
//! Evaluates every (G, TM-DV mode) candidate from the training sweep under
//! three hardware budgets — unconstrained, the paper's "minimal" (KAN1-
//! class) and "moderate" (KAN2-class) — and prints which design each budget
//! admits, mirroring how the paper derives its KAN1/KAN2 design points.
//!
//! ```sh
//! cargo run --release --example neurosim_search [artifacts-dir]
//! ```

use kan_edge::circuits::Tech;
use kan_edge::kan::checkpoint::Manifest;
use kan_edge::neurosim::{search, HwConstraints};

fn show(budget_name: &str, constraints: &HwConstraints, manifest: &Manifest) {
    let tech = Tech::default();
    let out = search(&[17, 1, 14], &manifest.sweep, &[2, 3, 4], constraints, &tech)
        .expect("search failed");
    println!("\n== budget: {budget_name} ==");
    println!(
        "  {:>4} {:>4} {:>8} {:>11} {:>11} {:>9} {:>7}",
        "G", "N", "acc", "area(mm2)", "energy(pJ)", "lat(ns)", "admit"
    );
    for c in &out.candidates {
        println!(
            "  {:>4} {:>4} {:>8.4} {:>11.4} {:>11.1} {:>9.0} {:>7}",
            c.g,
            c.tm_n,
            c.accuracy,
            c.report.area_mm2,
            c.report.energy_pj,
            c.report.latency_ns,
            if c.admitted { "yes" } else { "no" }
        );
        if !c.admitted {
            for v in &c.violations {
                println!("        rejected: {v}");
            }
        }
    }
    match &out.best {
        Some(b) => println!(
            "  -> picks G={} (N={}), accuracy {:.4}, {} params",
            b.g, b.tm_n, b.accuracy, b.report.num_params
        ),
        None => println!("  -> no admissible design point"),
    }
}

fn main() -> kan_edge::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&dir)?;
    println!(
        "KAN-NeuroSim search over trained sweep: G = {:?}",
        manifest.sweep.iter().map(|s| s.g).collect::<Vec<_>>()
    );
    show("none (accuracy only)", &HwConstraints::default(), &manifest);
    show("minimal (KAN1-class)", &HwConstraints::minimal(), &manifest);
    show("moderate (KAN2-class)", &HwConstraints::moderate(), &manifest);
    Ok(())
}
