//! ASP-KAN-HAQ walkthrough (paper §3.1, Fig 3-6, Fig 10).
//!
//! Shows, for a concrete (G, K, n) point, what each phase of the
//! quantization buys in hardware: the misalignment problem of conventional
//! quantization, the shared SH-LUT of Alignment-Symmetry, the bit-field
//! decode of PowerGap, and the resulting Fig 10 area/energy sweep.
//!
//! Needs no artifacts:
//!
//! ```sh
//! cargo run --release --example asp_quant_demo
//! ```

use kan_edge::circuits::{cost_bx_path, fig10_sweep, BxPathDesign, Tech};
use kan_edge::quant::{AspSpec, PactSpec, ShLut};

fn main() -> kan_edge::Result<()> {
    let (g, k, n) = (5u32, 3u32, 8u32);
    let t = Tech::default();

    // --- the conventional problem -----------------------------------------
    let pact = PactSpec::new(g, k, n, 0.0, 1.0);
    println!("== conventional (PACT-style) quantization, G={g} K={k} n={n} ==");
    println!("  grids aligned: {}", pact.grids_aligned());
    println!(
        "  -> every one of the {} basis functions needs its own {}-entry LUT",
        g + k,
        pact.per_basis_lut_entries()
    );

    // --- phase 1: Alignment-Symmetry ---------------------------------------
    let spec = AspSpec::build(g, k, n, 0.0, 1.0)?;
    let lut = ShLut::build(&spec, n);
    println!("\n== ASP phase 1: Alignment-Symmetry ==");
    println!(
        "  constrain codes to G*2^LD = {} (LD={}) -> zero grid offset",
        spec.range(),
        spec.ld
    );
    println!(
        "  one shared LUT: {} rows x {} cols; hemi storage = {} entries ({}% of full)",
        lut.full_rows(),
        k + 1,
        lut.stored_entries(),
        100 * lut.stored_entries() / (lut.full_rows() * (k as usize + 1))
    );

    // --- phase 2: PowerGap --------------------------------------------------
    println!("\n== ASP phase 2: PowerGap ==");
    let code = spec.quantize(0.37);
    let (j, l) = spec.decompose(code);
    println!(
        "  x=0.37 -> code {code} = (interval j={j}) << {} | (local l={l})",
        spec.ld
    );
    println!(
        "  decoders: one {}-bit + one {}-bit instead of one {n}-bit",
        n - spec.ld,
        spec.ld
    );

    // --- hardware cost of the three design points --------------------------
    println!("\n== B(X) path cost at G={g} (area um2 / energy fJ per lookup) ==");
    for design in [
        BxPathDesign::Conventional,
        BxPathDesign::AlignmentOnly,
        BxPathDesign::AspFull,
    ] {
        let r = cost_bx_path(design, g, k, n, &t)?;
        println!(
            "  {:<16} area {:>8.1}  energy {:>7.2}  (lut {:>7.1}, mux {:>6.1}, dec {:>7.1})",
            format!("{design:?}"),
            r.total.area_um2,
            r.total.energy_fj,
            r.lut.area_um2,
            r.mux.area_um2,
            r.decoder.area_um2
        );
    }

    // --- Fig 10 sweep --------------------------------------------------------
    println!("\n== Fig 10 sweep (paper: avg 40.14x area, 5.59x energy) ==");
    println!("  {:>4} {:>12} {:>14}", "G", "area-red(x)", "energy-red(x)");
    let rows = fig10_sweep(&[8, 16, 32, 64], k, n, &t)?;
    for r in &rows {
        println!(
            "  {:>4} {:>12.2} {:>14.2}",
            r.g, r.area_reduction, r.energy_reduction
        );
    }
    let nrows = rows.len() as f64;
    println!(
        "  avg: {:.2}x area, {:.2}x energy",
        rows.iter().map(|r| r.area_reduction).sum::<f64>() / nrows,
        rows.iter().map(|r| r.energy_reduction).sum::<f64>() / nrows
    );
    Ok(())
}
