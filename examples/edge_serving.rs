//! End-to-end edge-serving driver (the EXPERIMENTS.md validation run).
//!
//! Loads the AOT-compiled KAN graph on the PJRT CPU runtime, stands up the
//! full serving pipeline (admission → dynamic batcher → worker pool →
//! backend), fires a closed-loop load of concurrent clients with real test
//! samples, and reports latency percentiles, throughput, batch occupancy,
//! and online accuracy. Then repeats the measurement on the rust digital
//! backend for comparison.
//!
//! ```sh
//! cargo run --release --example edge_serving [artifacts-dir] [num-requests]
//! ```

#![allow(clippy::field_reassign_with_default)]

use std::sync::Arc;
use std::time::Duration;

use kan_edge::config::AppConfig;
use kan_edge::coordinator::batcher::BatchPolicy;
use kan_edge::coordinator::{
    build_session, BackendKind, InferenceService, ServeOptions,
};
use kan_edge::kan::checkpoint::{Dataset, Manifest};

fn run_load(
    name: &str,
    backend: Arc<dyn kan_edge::coordinator::ExecutionSession>,
    ds: &Dataset,
    total_requests: usize,
    clients: usize,
) {
    let opts = ServeOptions {
        policy: BatchPolicy { max_batch: 32, deadline: Duration::from_micros(60) },
        queue_depth: 4096,
        workers: 2,
        ..ServeOptions::default()
    };
    let svc = InferenceService::start(backend, opts);

    let rows: Vec<(Vec<f32>, u32)> =
        ds.test_rows().map(|(r, y)| (r.to_vec(), y)).collect();
    let per_client = total_requests / clients;
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let svc = svc.clone();
        let rows = rows.clone();
        handles.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for i in 0..per_client {
                let (x, y) = &rows[(c * per_client + i) % rows.len()];
                match svc.infer(x.clone()) {
                    Ok(logits) => {
                        let pred = kan_edge::kan::argmax(
                            &logits.iter().map(|&v| v as f64).collect::<Vec<_>>(),
                        );
                        if pred == *y as usize {
                            correct += 1;
                        }
                    }
                    Err(e) => panic!("request failed: {e}"),
                }
            }
            correct
        }));
    }
    let correct: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = t0.elapsed();
    let r = svc.metrics.report();
    println!("\n== {name} ==");
    println!("  requests:     {}", r.requests);
    println!("  wall time:    {:.2} s", wall.as_secs_f64());
    println!(
        "  throughput:   {:.0} req/s",
        r.requests as f64 / wall.as_secs_f64()
    );
    println!("  latency p50:  {} us", r.latency_p50_us);
    println!("  latency p99:  {} us", r.latency_p99_us);
    println!("  mean batch:   {:.1}", r.mean_batch);
    println!(
        "  online acc:   {:.4}",
        correct as f64 / (per_client * clients) as f64
    );
}

fn main() -> kan_edge::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let dir = args.get(1).cloned().unwrap_or_else(|| "artifacts".into());
    let total: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2000);

    let mut cfg = AppConfig::default();
    cfg.artifacts.dir = dir.clone();
    let manifest = Manifest::load(&dir)?;
    let ds = Dataset::load(&dir)?;
    println!(
        "edge serving driver: {} test samples, {} requests, model kan1",
        ds.test_y.len(),
        total
    );

    // PJRT backend: the AOT-compiled HLO graph (python never runs here)
    cfg.server.backend = BackendKind::Pjrt;
    let pjrt = build_session(&cfg, &manifest, "kan1")?;
    run_load("pjrt (AOT HLO on PJRT CPU)", pjrt, &ds, total, 8);

    // rust digital-reference backend (integer dataflow)
    cfg.server.backend = BackendKind::Digital;
    let digital = build_session(&cfg, &manifest, "kan1")?;
    run_load("digital (rust integer dataflow)", digital, &ds, total, 8);

    // analog ACIM simulator backend (IR-drop + noise + ADC, SAM mapping)
    cfg.server.backend = BackendKind::Acim;
    let acim = build_session(&cfg, &manifest, "kan1")?;
    run_load("acim (analog simulator, KAN-SAM)", acim, &ds, total.min(1000), 4);

    Ok(())
}
