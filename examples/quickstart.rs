//! Quickstart: load the trained KAN1 artifacts, run quantized inference on
//! the test set through the rust digital-reference path, and show the
//! ASP-KAN-HAQ geometry the hardware uses.
//!
//! Run after `make artifacts`:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use kan_edge::kan::checkpoint::{Dataset, Manifest};
use kan_edge::kan::QuantKanModel;
use kan_edge::quant::{AspSpec, ShLut};

fn main() -> kan_edge::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // 1. What did the build path produce?
    let manifest = Manifest::load(&dir)?;
    println!("== artifacts ==");
    let mut names: Vec<_> = manifest.models.keys().collect();
    names.sort();
    for name in &names {
        let m = &manifest.models[*name];
        println!(
            "  {name}: dims {:?}, {} params, quantized test acc {:.4}",
            m.dims,
            m.num_params,
            m.quant_test_acc.or(m.test_acc).unwrap_or(f64::NAN)
        );
    }

    // 2. Load KAN1 (the paper's 279-parameter knot-theory model).
    let model = QuantKanModel::load(format!("{dir}/kan1.weights.json"))?;
    println!("\n== kan1 ==");
    println!("  layers: {:?}, G={}, K={}", model.dims, model.g, model.k);

    // 3. The quantization geometry ASP-KAN-HAQ picked for layer 0.
    let spec: &AspSpec = &model.layers[0].spec;
    let lut: &ShLut = &model.layers[0].lut;
    println!(
        "  layer0: range [{:.3}, {:.3}], LD={}, codes R={}, SH-LUT {} rows x {} cols",
        spec.lo,
        spec.hi,
        spec.ld,
        spec.range(),
        lut.hemi.len(),
        spec.k + 1
    );

    // 4. One inference, end to end.
    let ds = Dataset::load(&dir)?;
    let (row, label) = ds.test_rows().next().expect("non-empty test set");
    let logits = model.forward(row);
    println!("\n== single inference ==");
    println!("  true class: {label}");
    println!("  predicted:  {}", kan_edge::kan::argmax(&logits));

    // 5. Accuracy over the whole artifact test split.
    let acc = model.accuracy(&ds);
    println!("\n== test accuracy (digital reference) ==");
    println!("  {:.4} over {} samples", acc, ds.test_y.len());
    Ok(())
}
