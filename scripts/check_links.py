#!/usr/bin/env python3
"""Dependency-free markdown link checker for the docs tree.

Scans docs/*.md plus the root README.md and ROADMAP.md for inline
markdown links `[text](target)` and verifies that every *relative*
target resolves to an existing file (fragments are stripped; external
http(s)/mailto links are skipped — CI must not depend on the network).

Also enforces index completeness: every docs/*.md (other than the
index itself) must be linked from docs/README.md, so a new subsystem
document cannot land without registering itself in the reading index.

Exit status: 0 when every link resolves and the index is complete,
1 otherwise (one line per problem). Run from the repository root:

    python3 scripts/check_links.py
"""

import re
import sys
from pathlib import Path

# inline links only; reference-style links are not used in this repo.
# [text](target) with no whitespace/paren inside the target.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def collect_sources(root: Path):
    sources = sorted((root / "docs").glob("*.md"))
    for name in ("README.md", "ROADMAP.md"):
        p = root / name
        if p.exists():
            sources.append(p)
    return sources


def check_file(path: Path, root: Path):
    """Yield (line_no, target, resolved) for each broken link in path."""
    text = path.read_text(encoding="utf-8")
    in_fence = False
    for line_no, line in enumerate(text.splitlines(), start=1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES):
                continue
            # strip fragment; a bare '#section' always refers to this file
            file_part = target.split("#", 1)[0]
            if not file_part:
                continue
            resolved = (path.parent / file_part).resolve()
            try:
                resolved.relative_to(root.resolve())
            except ValueError:
                yield line_no, target, "escapes the repository"
                continue
            if not resolved.exists():
                yield line_no, target, "missing"


def check_index(root: Path):
    """Yield names of docs/*.md files docs/README.md does not link."""
    index = root / "docs" / "README.md"
    if not index.exists():
        return
    linked = set()
    for m in LINK_RE.finditer(index.read_text(encoding="utf-8")):
        file_part = m.group(1).split("#", 1)[0]
        if file_part:
            linked.add(Path(file_part).name)
    for doc in sorted((root / "docs").glob("*.md")):
        if doc.name != "README.md" and doc.name not in linked:
            yield doc.name


def main():
    root = Path(__file__).resolve().parent.parent
    sources = collect_sources(root)
    if not sources:
        print("check_links: no markdown sources found", file=sys.stderr)
        return 1
    broken = 0
    for src in sources:
        for line_no, target, why in check_file(src, root):
            rel = src.relative_to(root)
            print(f"{rel}:{line_no}: broken link '{target}' ({why})")
            broken += 1
    for name in check_index(root):
        print(f"docs/README.md: docs/{name} is not linked from the index")
        broken += 1
    checked = ", ".join(str(s.relative_to(root)) for s in sources)
    if broken:
        print(f"check_links: {broken} broken link(s) across: {checked}")
        return 1
    print(f"check_links: OK ({len(sources)} files: {checked})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
